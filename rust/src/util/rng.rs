//! Deterministic PRNG (SplitMix64 core + helpers) — no `rand` crate in the
//! offline registry. Quality is more than sufficient for workload synthesis
//! and property-test case generation; determinism (seed → same dataset) is
//! what we actually require for reproducibility.

/// SplitMix64: tiny, fast, passes BigCrush when used as a stream.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng { state: seed }
    }

    /// Derive an independent child stream (for per-tape generators).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)` (Lemire's rejection-free-ish method).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Log-normal with the given *log-space* mean and standard deviation.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Zipf-ish discrete draw over `{1..=n}` with exponent `s` (inverse-CDF
    /// on the fly; fine for the modest `n` we use).
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        debug_assert!(n >= 1);
        let h: f64 = (1..=n).map(|i| (i as f64).powf(-s)).sum();
        let mut target = self.f64() * h;
        for i in 1..=n {
            target -= (i as f64).powf(-s);
            if target <= 0.0 {
                return i;
            }
        }
        n
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `m` distinct indices from `0..n` (partial shuffle).
    pub fn sample_indices(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..m {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(m);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
            let v = r.range(5, 9);
            assert!((5..=9).contains(&v));
        }
    }

    #[test]
    fn f64_unit_interval_and_mean() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..100_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / 100_000.0 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 30);
        assert!(s.iter().all(|&i| i < 100));
    }

    #[test]
    fn zipf_skew() {
        let mut r = Rng::new(9);
        let mut c1 = 0;
        for _ in 0..10_000 {
            if r.zipf(100, 1.2) == 1 {
                c1 += 1;
            }
        }
        // P(1) ≈ 1/H ≈ 0.25 at s=1.2, n=100.
        assert!(c1 > 1_500, "rank-1 mass too small: {c1}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(1);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }
}
