//! Summary-statistics helpers shared by dataset stats, the bench framework
//! and the analysis harness.

/// Min / max / median / mean of a numeric sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub mean: f64,
}

/// Compute a [`Summary`]; returns zeros for an empty slice.
pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary { min: 0.0, max: 0.0, median: 0.0, mean: 0.0 };
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        min: v[0],
        max: *v.last().unwrap(),
        median: percentile_sorted(&v, 50.0),
        mean: v.iter().sum::<f64>() / v.len() as f64,
    }
}

/// Percentile (linear interpolation) over a **sorted** slice; `p` in 0..=100.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
    var.sqrt()
}

/// Coefficient of variation (stddev / mean), as a fraction.
pub fn cv(xs: &[f64]) -> f64 {
    let mean = xs.iter().sum::<f64>() / xs.len().max(1) as f64;
    if mean == 0.0 {
        0.0
    } else {
        stddev(xs) / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[3.0, 1.0, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.mean, 2.0);
    }

    #[test]
    fn summary_empty() {
        assert_eq!(summarize(&[]).mean, 0.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let v = [0.0, 10.0, 20.0, 30.0];
        assert_eq!(percentile_sorted(&v, 0.0), 0.0);
        assert_eq!(percentile_sorted(&v, 100.0), 30.0);
        assert_eq!(percentile_sorted(&v, 50.0), 15.0);
        assert_eq!(percentile_sorted(&v, 25.0), 7.5);
        assert_eq!(percentile_sorted(&[5.0], 70.0), 5.0);
    }

    #[test]
    fn cv_matches_hand_calc() {
        // values 5, 15: mean 10, stddev 5 → CV 0.5
        assert!((cv(&[5.0, 15.0]) - 0.5).abs() < 1e-12);
        assert_eq!(cv(&[0.0, 0.0]), 0.0);
    }
}
