//! FxHash-style fast hasher for the DP memo tables.
//!
//! The standard `HashMap` default (SipHash-1-3) is DoS-resistant but ~4×
//! slower on the 8-byte packed keys the DP uses billions of times; this is
//! the classic Firefox `FxHasher` multiply-rotate scheme.

use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// Multiply-rotate hasher specialized for small integer keys.
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, i: u64) {
        self.hash = (self.hash.rotate_left(5) ^ i).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add_to_hash(i);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;
pub type FxHashSet<K> = std::collections::HashSet<K, FxBuildHasher>;

/// The murmur3 64-bit finalizer: a full-avalanche bijection on `u64`.
///
/// Used wherever a *stable* well-mixed hash is needed (consistent-hash
/// ring points, instance fingerprints): unlike `DefaultHasher`, the output
/// is fixed across processes, runs, and platforms, which is what makes
/// cluster routing byte-deterministic.
#[inline]
pub fn fmix64(mut h: u64) -> u64 {
    h ^= h >> 33;
    h = h.wrapping_mul(0xff51_afd7_ed55_8ccd);
    h ^= h >> 33;
    h = h.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    h ^ (h >> 33)
}

/// Stable, well-distributed 64-bit hash of a byte string: FNV-1a over the
/// bytes followed by [`fmix64`]. Deterministic across runs and platforms
/// (no per-process seeding), so anything keyed on it — shard routing in
/// particular — reproduces byte-for-byte.
pub fn stable_hash64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    fmix64(h)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_roundtrip() {
        let mut m: FxHashMap<u64, u64> = FxHashMap::default();
        for i in 0..10_000u64 {
            m.insert(i.wrapping_mul(0x9E37_79B9_7F4A_7C15), i);
        }
        for i in 0..10_000u64 {
            assert_eq!(m[&i.wrapping_mul(0x9E37_79B9_7F4A_7C15)], i);
        }
    }

    #[test]
    fn hasher_distinguishes_packed_keys() {
        // The DP packs (a, b, ns) into one u64; nearby keys must not collide
        // in the low bits catastrophically.
        let h = |k: u64| {
            let mut hasher = FxHasher::default();
            hasher.write_u64(k);
            hasher.finish()
        };
        let mut seen = std::collections::HashSet::new();
        for a in 0..16u64 {
            for b in 0..16u64 {
                for ns in 0..64u64 {
                    seen.insert(h(a << 52 | b << 40 | ns));
                }
            }
        }
        assert_eq!(seen.len(), 16 * 16 * 64);
    }

    #[test]
    fn stable_hash_is_stable_and_spreads() {
        // Stability: pin concrete output values (computed independently
        // from the FNV-1a + fmix64 definition) — a refactor that silently
        // changes the function fails loudly here rather than remapping
        // every tape in every deployed ring.
        assert_eq!(stable_hash64(b"TAPE001"), 0xc2a5_b31a_f521_e84b);
        assert_eq!(stable_hash64(b"shard0:vnode0"), 0x8eaf_1e54_fd6d_0585);
        assert_ne!(stable_hash64(b"TAPE001"), stable_hash64(b"TAPE002"));
        // Spread: hashing many similar keys must not collide.
        let mut seen = std::collections::HashSet::new();
        for i in 0..10_000 {
            seen.insert(stable_hash64(format!("TAPE{i:05}").as_bytes()));
        }
        assert_eq!(seen.len(), 10_000);
        // fmix64 is a bijection: distinct inputs stay distinct.
        let mut out = std::collections::HashSet::new();
        for i in 0..10_000u64 {
            out.insert(fmix64(i));
        }
        assert_eq!(out.len(), 10_000);
    }
}
