//! Poison-tolerant lock helpers for serving paths.
//!
//! The panic policy (enforced by `tapesched audit`) forbids
//! `.unwrap()`/`.expect(` in `net/`, `obs/expo.rs`, and
//! `coordinator/service.rs`: a panicked worker must degrade the service,
//! not abort it. A poisoned `Mutex`/`RwLock` is exactly that case — some
//! thread died mid-critical-section — and for this crate's state
//! (metrics counters, connection slots, membership tables) the data is
//! still structurally valid: every critical section leaves the guarded
//! value consistent at each await-free step, so the right response is to
//! log once and keep serving, not to cascade the panic through every
//! thread that touches the lock.
//!
//! These helpers centralize that choice: they recover the guard from a
//! [`PoisonError`] and emit one `stderr` line so the original panic
//! (already printed by the runtime) is traceable to its blast radius.

use std::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::time::Duration;

fn note_poison(what: &str, context: &str) {
    eprintln!("tapesched: {what} poisoned in {context}; recovering and continuing");
}

/// Lock `m`, recovering (with a logged note) if a holder panicked.
pub fn lock_recover<'a, T>(m: &'a Mutex<T>, context: &str) -> MutexGuard<'a, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => {
            note_poison("mutex", context);
            poisoned.into_inner()
        }
    }
}

/// Read-lock `l`, recovering if a writer panicked.
pub fn read_recover<'a, T>(l: &'a RwLock<T>, context: &str) -> RwLockReadGuard<'a, T> {
    match l.read() {
        Ok(g) => g,
        Err(poisoned) => {
            note_poison("rwlock(read)", context);
            poisoned.into_inner()
        }
    }
}

/// Write-lock `l`, recovering if a holder panicked.
pub fn write_recover<'a, T>(l: &'a RwLock<T>, context: &str) -> RwLockWriteGuard<'a, T> {
    match l.write() {
        Ok(g) => g,
        Err(poisoned) => {
            note_poison("rwlock(write)", context);
            poisoned.into_inner()
        }
    }
}

/// Wait on `cv`, recovering the guard if the mutex was poisoned while
/// parked. Spurious-wakeup semantics are unchanged: callers keep their
/// usual predicate loop.
pub fn wait_recover<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    context: &str,
) -> MutexGuard<'a, T> {
    match cv.wait(guard) {
        Ok(g) => g,
        Err(poisoned) => {
            note_poison("condvar mutex", context);
            poisoned.into_inner()
        }
    }
}

/// Timed wait on `cv` with poison recovery. The timeout flag is dropped:
/// every call site in this crate re-checks its predicate and deadline in
/// a loop, so "woke by timeout" and "woke spuriously" are handled the
/// same way.
pub fn wait_timeout_recover<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
    context: &str,
) -> MutexGuard<'a, T> {
    match cv.wait_timeout(guard, dur) {
        Ok((g, _)) => g,
        Err(poisoned) => {
            note_poison("condvar mutex", context);
            poisoned.into_inner().0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_recover_survives_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        let g = lock_recover(&m, "test");
        assert_eq!(*g, 7);
    }

    #[test]
    fn rwlock_recover_survives_poison() {
        let l = Arc::new(RwLock::new(3u32));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison it");
        })
        .join();
        assert_eq!(*read_recover(&l, "test"), 3);
        *write_recover(&l, "test") = 4;
        assert_eq!(*read_recover(&l, "test"), 4);
    }

    #[test]
    fn wait_timeout_recover_returns_after_deadline() {
        let m = Mutex::new(false);
        let cv = Condvar::new();
        let g = lock_recover(&m, "test");
        let g = wait_timeout_recover(&cv, g, Duration::from_millis(5), "test");
        assert!(!*g);
    }
}
