//! Full-evaluation driver: run every scheduler on every instance of a
//! dataset for one U value, recording costs and wall-clock times — the data
//! behind Figures 14–16 and the §5.3 timing table. Plus the cross-policy
//! QoS comparison table distilled from replay reports.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::dataset::{Dataset, TapeData};
use crate::model::{virtual_lb, Cost};
use crate::replay::QosReport;
use crate::sched::Scheduler;
use crate::sim::evaluate;

use super::profile::{curves_csv, performance_profile, paper_tau_grid, ProfileCurve};

/// Result of one `(algorithm, instance)` run.
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub algorithm: String,
    pub tape: String,
    pub cost: Cost,
    pub virtual_lb: Cost,
    pub n_detours: usize,
    pub seconds: f64,
}

/// All records of an evaluation sweep at a fixed U.
#[derive(Debug, Clone)]
pub struct EvalTable {
    pub u: u64,
    pub records: Vec<EvalRecord>,
    /// Algorithm names in run order (reference algorithm included).
    pub algorithms: Vec<String>,
}

impl EvalTable {
    /// Per-instance `(cost, reference cost)` pairs for `algo`, where the
    /// reference is `reference_algo` (normally `"DP"`).
    pub fn cost_pairs(&self, algo: &str, reference_algo: &str) -> Vec<(Cost, Cost)> {
        let refc: std::collections::HashMap<&str, Cost> = self
            .records
            .iter()
            .filter(|r| r.algorithm == reference_algo)
            .map(|r| (r.tape.as_str(), r.cost))
            .collect();
        self.records
            .iter()
            .filter(|r| r.algorithm == algo)
            .map(|r| (r.cost, refc[r.tape.as_str()]))
            .collect()
    }

    /// Build the performance-profile curves of Figures 14–16 (all
    /// algorithms except the reference, normalized by the reference).
    pub fn profiles(&self, reference_algo: &str) -> Vec<ProfileCurve> {
        let taus = paper_tau_grid();
        self.algorithms
            .iter()
            .filter(|a| *a != reference_algo)
            .map(|a| performance_profile(a, &self.cost_pairs(a, reference_algo), &taus))
            .collect()
    }

    /// Median wall-clock seconds per algorithm (§5.3 timing table).
    pub fn median_times(&self) -> Vec<(String, f64)> {
        self.algorithms
            .iter()
            .map(|a| {
                let mut ts: Vec<f64> = self
                    .records
                    .iter()
                    .filter(|r| &r.algorithm == a)
                    .map(|r| r.seconds)
                    .collect();
                ts.sort_by(|x, y| x.partial_cmp(y).unwrap());
                let med = if ts.is_empty() { 0.0 } else { ts[ts.len() / 2] };
                (a.clone(), med)
            })
            .collect()
    }

    /// Raw records as CSV (matches the artifact's `results.csv` role).
    pub fn records_csv(&self) -> String {
        let mut out =
            String::from("algorithm,tape,u,cost,virtual_lb,n_detours,seconds\n");
        for r in &self.records {
            out.push_str(&format!(
                "{},{},{},{},{},{},{:.6}\n",
                r.algorithm, r.tape, self.u, r.cost, r.virtual_lb, r.n_detours, r.seconds
            ));
        }
        out
    }

    /// Profile curves as CSV (one figure's worth of data).
    pub fn profiles_csv(&self, reference_algo: &str) -> String {
        curves_csv(&self.profiles(reference_algo))
    }
}

/// Run `schedulers` over every tape of `ds` at penalty `u`.
///
/// `max_k` skips instances with more requested files than the cap (used to
/// keep exact-DP sweeps tractable in CI; `None` = run everything).
///
/// Tapes are independent LTSP instances, so the sweep fans out over a
/// scoped `std::thread` pool (one worker per core, at most one per tape —
/// the coordinator's drive-pool pattern, minus the channels). Records land
/// in per-tape slots and are flattened in tape order, so the output is
/// byte-for-byte what the sequential sweep produced (wall-clock `seconds`
/// aside).
pub fn run_evaluation(
    ds: &Dataset,
    schedulers: &[Box<dyn Scheduler + Send + Sync>],
    u: u64,
    max_k: Option<usize>,
) -> EvalTable {
    run_evaluation_with_threads(ds, schedulers, u, max_k, None)
}

/// [`run_evaluation`] with an explicit worker count: `threads` caps the
/// sweep's thread pool (`None` = one worker per core). The records are
/// identical for any value — the pool only changes the wall-clock
/// `seconds` fields — so `figures --threads N` can trade latency for
/// machine share without touching the figures.
pub fn run_evaluation_with_threads(
    ds: &Dataset,
    schedulers: &[Box<dyn Scheduler + Send + Sync>],
    u: u64,
    max_k: Option<usize>,
    threads: Option<usize>,
) -> EvalTable {
    let names: Vec<String> = schedulers.iter().map(|s| s.name()).collect();
    let work: Vec<&TapeData> = ds
        .tapes
        .iter()
        .filter(|t| max_k.map_or(true, |cap| t.n_req() <= cap))
        .collect();
    let n_workers = threads
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
        .min(work.len())
        .max(1);
    let slots: Vec<Mutex<Vec<EvalRecord>>> =
        (0..work.len()).map(|_| Mutex::new(Vec::new())).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..n_workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                let Some(t) = work.get(i) else { break };
                let inst = t.instance(u).expect("dataset tapes are valid instances");
                let lb = virtual_lb(&inst);
                let mut recs = Vec::with_capacity(schedulers.len());
                for s in schedulers {
                    let start = Instant::now();
                    let sched = s.schedule(&inst);
                    let seconds = start.elapsed().as_secs_f64();
                    let out = evaluate(&inst, &sched);
                    recs.push(EvalRecord {
                        algorithm: s.name(),
                        tape: t.tape.name.clone(),
                        cost: out.cost,
                        virtual_lb: lb,
                        n_detours: sched.len(),
                        seconds,
                    });
                }
                *slots[i].lock().unwrap() = recs;
            });
        }
    });
    let records = slots
        .into_iter()
        .flat_map(|m| m.into_inner().unwrap())
        .collect();
    EvalTable { u, records, algorithms: names }
}

/// Cross-policy QoS comparison: the replay analogue of the §5 cost tables.
/// One row per report (one replay per policy over the same arrival
/// stream); latencies in seconds.
pub fn qos_comparison(reports: &[QosReport]) -> String {
    let mut out = format!(
        "{:<18} {:>9} {:>6} {:>9} {:>9} {:>9} {:>9} {:>10} {:>6}\n",
        "policy", "completed", "shed", "p50 lat", "p95 lat", "p99 lat", "p99.9", "mean svc", "util%"
    );
    for r in reports {
        out.push_str(&format!(
            "{:<18} {:>9} {:>6} {:>9.1} {:>9.1} {:>9.1} {:>9.1} {:>10.1} {:>6.1}\n",
            r.policy,
            r.completed,
            r.shed,
            r.latency.p50_s,
            r.latency.p95_s,
            r.latency.p99_s,
            r.latency.p999_s,
            r.service.mean_s,
            r.drive_utilization * 100.0,
        ));
    }
    out
}

/// Shard-imbalance summary for a sharded replay report: one row per
/// library shard (tapes owned, ring key-space share, load, tail latency,
/// utilization) plus the imbalance footer — max/min shard load and the
/// ring spread extremes. This is the fleet-partitioning diagnostic: per
/// the sharding literature, fleet service time is dominated by how
/// requests split across devices *before* any per-device ordering runs.
pub fn shard_summary(r: &QosReport) -> String {
    let mut out = format!(
        "{:<6} {:>6} {:>7} {:>10} {:>8} {:>6} {:>9} {:>9} {:>6}\n",
        "shard", "tapes", "share%", "completed", "batches", "shed", "p99 lat", "p99.9", "util%"
    );
    for s in &r.shards {
        out.push_str(&format!(
            "{:<6} {:>6} {:>7.2} {:>10} {:>8} {:>6} {:>9.1} {:>9.1} {:>6.1}\n",
            s.shard,
            s.tapes,
            s.ring_share * 100.0,
            s.completed,
            s.batches,
            s.shed,
            s.latency.p99_s,
            s.latency.p999_s,
            s.drive_utilization * 100.0,
        ));
    }
    let max = r.shards.iter().map(|s| s.completed).max().unwrap_or(0);
    let min = r.shards.iter().map(|s| s.completed).min().unwrap_or(0);
    let ratio = if min > 0 {
        format!("{:.2}", max as f64 / min as f64)
    } else if max > 0 {
        "inf".to_string()
    } else {
        "1.00".to_string()
    };
    let share_max =
        r.shards.iter().map(|s| s.ring_share).fold(f64::NEG_INFINITY, f64::max);
    let share_min = r.shards.iter().map(|s| s.ring_share).fold(f64::INFINITY, f64::min);
    out.push_str(&format!(
        "imbalance: max/min shard load = {max}/{min} (ratio {ratio}); \
         ring spread ∈ [{:.2}%, {:.2}%]\n",
        share_min * 100.0,
        share_max * 100.0,
    ));
    out
}

/// Mount-pipeline summary for a replay run with the arm pool and/or drive
/// affinity active: remount economics plus the three pipeline wait
/// ladders (per-op arm wait, per-batch mount-pipeline latency, per-batch
/// free-drive wait). Rendered on stderr next to the QoS table — these are
/// exactly the components the fixed mount-cost model hides, and the ones
/// that dominate p99.9 on a contended library.
pub fn mount_summary(r: &QosReport) -> String {
    let total = r.remount_hits + r.remount_misses;
    let hit_pct = if total > 0 {
        r.remount_hits as f64 / total as f64 * 100.0
    } else {
        0.0
    };
    let mut out = format!(
        "mount pipeline: arms={} affinity={} | remounts hit/miss = {}/{} ({:.1}% hit)\n",
        if r.arms == 0 { "∞".to_string() } else { r.arms.to_string() },
        r.affinity,
        r.remount_hits,
        r.remount_misses,
        hit_pct,
    );
    for (name, l) in [
        ("arm wait", &r.arm_wait),
        ("mount wait", &r.mount_wait),
        ("drive wait", &r.drive_wait),
    ] {
        out.push_str(&format!(
            "  {name:<10} p50/p99/p99.9 = {:>8.1} / {:>8.1} / {:>8.1} s (max {:.1})\n",
            l.p50_s, l.p99_s, l.p999_s, l.max_s,
        ));
    }
    out
}

/// Cartridge-exclusivity summary for a replay run with `--exclusive-tapes
/// on`: how many batches parked on a cartridge waitlist (their tape was
/// threaded or mid-mount in another drive) and the per-batch wait ladder.
/// This is the head-of-line component the pre-exclusivity model hid by
/// mounting "copies" of a hot tape in several drives at once.
pub fn cartridge_summary(r: &QosReport) -> String {
    let mut out = format!(
        "cartridge exclusivity: {} of {} batches parked on a cartridge waitlist\n",
        r.cartridge_parks, r.batches,
    );
    let l = &r.cartridge_wait;
    out.push_str(&format!(
        "  cart wait   p50/p99/p99.9 = {:>8.1} / {:>8.1} / {:>8.1} s (max {:.1})\n",
        l.p50_s, l.p99_s, l.p999_s, l.max_s,
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{generate_dataset, GeneratorConfig};
    use crate::sched::{Dp, Gs, NoDetour};

    fn small_ds() -> Dataset {
        // Shrink the marginals so DP runs fast in tests.
        generate_dataset(&GeneratorConfig {
            n_tapes: 6,
            nf: (30, 60.0, 70.0, 120),
            nreq: (5, 10.0, 12.0, 20),
            n: (10, 30.0, 40.0, 80),
            ..Default::default()
        })
    }

    fn algos() -> Vec<Box<dyn Scheduler + Send + Sync>> {
        vec![Box::new(NoDetour), Box::new(Gs), Box::new(Dp)]
    }

    #[test]
    fn evaluation_produces_full_grid() {
        let ds = small_ds();
        let table = run_evaluation(&ds, &algos(), 0, None);
        assert_eq!(table.records.len(), 3 * ds.tapes.len());
        // DP is the reference: zero overhead everywhere.
        for (c, r) in table.cost_pairs("DP", "DP") {
            assert_eq!(c, r);
        }
        // Everyone ≥ DP ≥ VirtualLB.
        for rec in &table.records {
            assert!(rec.cost >= rec.virtual_lb);
        }
        for algo in ["NoDetour", "GS"] {
            for (c, r) in table.cost_pairs(algo, "DP") {
                assert!(c >= r, "{algo}: {c} < {r}");
            }
        }
    }

    #[test]
    fn profiles_are_monotone_and_dp_reference_excluded() {
        let ds = small_ds();
        let table = run_evaluation(&ds, &algos(), 1000, None);
        let curves = table.profiles("DP");
        assert_eq!(curves.len(), 2);
        for c in &curves {
            for w in c.points.windows(2) {
                assert!(w[0].fraction <= w[1].fraction, "{}", c.algorithm);
            }
            let last = c.points.last().unwrap();
            assert!(last.fraction <= 1.0);
        }
    }

    #[test]
    fn parallel_sweep_matches_itself_structurally() {
        // The thread pool must not perturb record order or contents
        // (wall-clock `seconds` aside): two sweeps agree field-by-field.
        let ds = small_ds();
        let a = run_evaluation(&ds, &algos(), 500, None);
        let b = run_evaluation(&ds, &algos(), 500, None);
        assert_eq!(a.records.len(), b.records.len());
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.algorithm, y.algorithm);
            assert_eq!(x.tape, y.tape);
            assert_eq!(x.cost, y.cost);
            assert_eq!(x.virtual_lb, y.virtual_lb);
            assert_eq!(x.n_detours, y.n_detours);
        }
        // Records stay grouped by tape in dataset order, schedulers in
        // declaration order inside each tape (the sequential layout).
        for chunk in a.records.chunks(3) {
            assert_eq!(chunk.len(), 3);
            assert!(chunk.iter().all(|r| r.tape == chunk[0].tape));
            assert_eq!(chunk[0].algorithm, "NoDetour");
            assert_eq!(chunk[2].algorithm, "DP");
        }
    }

    #[test]
    fn qos_comparison_renders_one_row_per_report() {
        use crate::model::Tape;
        use crate::replay::{run_replay, PoissonArrivals, ReplayConfig, RequestMix};
        let catalog = vec![Tape::from_sizes("T0", &[1_000; 30])];
        let cfg = ReplayConfig::default();
        let mut reports = Vec::new();
        for policy in ["GS", "SimpleDP"] {
            let p = crate::sched::scheduler_by_name(policy).unwrap();
            let mut model =
                PoissonArrivals::new(RequestMix::new(&catalog), 20.0, 5.0, 3);
            let (r, _) = run_replay(&cfg, &catalog, p.as_ref(), &mut model, 3, 5.0);
            reports.push(r);
        }
        let table = qos_comparison(&reports);
        let lines: Vec<&str> = table.lines().collect();
        assert_eq!(lines.len(), 3, "header + one row per policy:\n{table}");
        assert!(lines[0].contains("p99"));
        assert!(lines[1].starts_with("GS"));
        assert!(lines[2].starts_with("SimpleDP"));
    }

    #[test]
    fn shard_summary_renders_one_row_per_shard_plus_footer() {
        use crate::model::Tape;
        use crate::replay::{run_replay, PoissonArrivals, ReplayConfig, RequestMix};
        let catalog: Vec<Tape> =
            (0..12).map(|i| Tape::from_sizes(format!("T{i:02}"), &[1_000; 30])).collect();
        let cfg = ReplayConfig { n_shards: 3, vnodes: 64, ..ReplayConfig::default() };
        let p = crate::sched::scheduler_by_name("GS").unwrap();
        let mut model = PoissonArrivals::new(RequestMix::new(&catalog), 20.0, 5.0, 3);
        let (r, _) = run_replay(&cfg, &catalog, p.as_ref(), &mut model, 3, 5.0);
        let table = shard_summary(&r);
        let lines: Vec<&str> = table.lines().collect();
        assert_eq!(lines.len(), 1 + 3 + 1, "header + one row per shard + footer:\n{table}");
        assert!(lines[0].contains("share%"));
        assert!(lines.last().unwrap().starts_with("imbalance:"));
        assert!(lines.last().unwrap().contains("ring spread"));
    }

    #[test]
    fn mount_summary_renders_pipeline_lines() {
        use crate::model::Tape;
        use crate::replay::{run_replay, PoissonArrivals, ReplayConfig, RequestMix};
        use crate::sim::{Affinity, DriveParams};
        let catalog = vec![Tape::from_sizes("T0", &[1_000; 30])];
        let cfg = ReplayConfig {
            drive: DriveParams { n_arms: 1, ..DriveParams::default() },
            affinity: Affinity::Lru,
            ..ReplayConfig::default()
        };
        let p = crate::sched::scheduler_by_name("GS").unwrap();
        let mut model = PoissonArrivals::new(RequestMix::new(&catalog), 5.0, 5.0, 3);
        let (r, _) = run_replay(&cfg, &catalog, p.as_ref(), &mut model, 3, 5.0);
        assert!(r.pipeline);
        let table = mount_summary(&r);
        assert!(table.starts_with("mount pipeline: arms=1 affinity=lru"));
        assert!(table.contains("% hit)"));
        for name in ["arm wait", "mount wait", "drive wait"] {
            assert!(table.contains(name), "missing {name}:\n{table}");
        }
        assert_eq!(table.lines().count(), 4, "header + three ladders:\n{table}");
    }

    #[test]
    fn cartridge_summary_renders_the_exclusivity_lines() {
        use crate::model::Tape;
        use crate::replay::{run_replay, PoissonArrivals, ReplayConfig, RequestMix};
        use crate::coordinator::BatcherConfig;
        let catalog = vec![Tape::from_sizes("HOT", &[1_000; 30])];
        let cfg = ReplayConfig {
            n_drives: 8,
            batcher: BatcherConfig { max_batch: 1, ..BatcherConfig::default() },
            ..ReplayConfig::default()
        };
        assert!(cfg.exclusive_tapes, "exclusivity is the default");
        let p = crate::sched::scheduler_by_name("GS").unwrap();
        let mut model = PoissonArrivals::new(RequestMix::new(&catalog), 10.0, 3.0, 3);
        let (r, _) = run_replay(&cfg, &catalog, p.as_ref(), &mut model, 3, 3.0);
        assert!(r.exclusive);
        assert!(r.cartridge_parks > 0, "hot singleton batches must park");
        let table = cartridge_summary(&r);
        assert!(table.starts_with("cartridge exclusivity:"));
        assert!(table.contains("parked on a cartridge waitlist"));
        assert!(table.contains("cart wait"));
        assert_eq!(table.lines().count(), 2, "header + ladder:\n{table}");
    }

    #[test]
    fn explicit_thread_counts_reproduce_the_sweep() {
        // `--threads N` is a machine-share knob, never a result knob:
        // every pool width yields the default sweep's records.
        let ds = small_ds();
        let a = run_evaluation(&ds, &algos(), 500, None);
        for threads in [1usize, 2, 7] {
            let b = run_evaluation_with_threads(&ds, &algos(), 500, None, Some(threads));
            assert_eq!(a.records.len(), b.records.len(), "threads={threads}");
            for (x, y) in a.records.iter().zip(&b.records) {
                assert_eq!(x.algorithm, y.algorithm, "threads={threads}");
                assert_eq!(x.tape, y.tape, "threads={threads}");
                assert_eq!(x.cost, y.cost, "threads={threads}");
                assert_eq!(x.n_detours, y.n_detours, "threads={threads}");
            }
        }
    }

    #[test]
    fn max_k_filters_instances() {
        let ds = small_ds();
        let all = run_evaluation(&ds, &algos(), 0, None);
        let few = run_evaluation(&ds, &algos(), 0, Some(1));
        assert!(few.records.len() < all.records.len());
    }

    #[test]
    fn csv_outputs() {
        let ds = small_ds();
        let table = run_evaluation(&ds, &algos(), 0, None);
        assert!(table.records_csv().starts_with("algorithm,tape,"));
        assert!(table.profiles_csv("DP").starts_with("tau_pct,"));
        let times = table.median_times();
        assert_eq!(times.len(), 3);
    }
}
