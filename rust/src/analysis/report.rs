//! Full-evaluation driver: run every scheduler on every instance of a
//! dataset for one U value, recording costs and wall-clock times — the data
//! behind Figures 14–16 and the §5.3 timing table.

use std::time::Instant;

use crate::dataset::Dataset;
use crate::model::{virtual_lb, Cost};
use crate::sched::Scheduler;
use crate::sim::evaluate;

use super::profile::{curves_csv, performance_profile, paper_tau_grid, ProfileCurve};

/// Result of one `(algorithm, instance)` run.
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub algorithm: String,
    pub tape: String,
    pub cost: Cost,
    pub virtual_lb: Cost,
    pub n_detours: usize,
    pub seconds: f64,
}

/// All records of an evaluation sweep at a fixed U.
#[derive(Debug, Clone)]
pub struct EvalTable {
    pub u: u64,
    pub records: Vec<EvalRecord>,
    /// Algorithm names in run order (reference algorithm included).
    pub algorithms: Vec<String>,
}

impl EvalTable {
    /// Per-instance `(cost, reference cost)` pairs for `algo`, where the
    /// reference is `reference_algo` (normally `"DP"`).
    pub fn cost_pairs(&self, algo: &str, reference_algo: &str) -> Vec<(Cost, Cost)> {
        let refc: std::collections::HashMap<&str, Cost> = self
            .records
            .iter()
            .filter(|r| r.algorithm == reference_algo)
            .map(|r| (r.tape.as_str(), r.cost))
            .collect();
        self.records
            .iter()
            .filter(|r| r.algorithm == algo)
            .map(|r| (r.cost, refc[r.tape.as_str()]))
            .collect()
    }

    /// Build the performance-profile curves of Figures 14–16 (all
    /// algorithms except the reference, normalized by the reference).
    pub fn profiles(&self, reference_algo: &str) -> Vec<ProfileCurve> {
        let taus = paper_tau_grid();
        self.algorithms
            .iter()
            .filter(|a| *a != reference_algo)
            .map(|a| performance_profile(a, &self.cost_pairs(a, reference_algo), &taus))
            .collect()
    }

    /// Median wall-clock seconds per algorithm (§5.3 timing table).
    pub fn median_times(&self) -> Vec<(String, f64)> {
        self.algorithms
            .iter()
            .map(|a| {
                let mut ts: Vec<f64> = self
                    .records
                    .iter()
                    .filter(|r| &r.algorithm == a)
                    .map(|r| r.seconds)
                    .collect();
                ts.sort_by(|x, y| x.partial_cmp(y).unwrap());
                let med = if ts.is_empty() { 0.0 } else { ts[ts.len() / 2] };
                (a.clone(), med)
            })
            .collect()
    }

    /// Raw records as CSV (matches the artifact's `results.csv` role).
    pub fn records_csv(&self) -> String {
        let mut out =
            String::from("algorithm,tape,u,cost,virtual_lb,n_detours,seconds\n");
        for r in &self.records {
            out.push_str(&format!(
                "{},{},{},{},{},{},{:.6}\n",
                r.algorithm, r.tape, self.u, r.cost, r.virtual_lb, r.n_detours, r.seconds
            ));
        }
        out
    }

    /// Profile curves as CSV (one figure's worth of data).
    pub fn profiles_csv(&self, reference_algo: &str) -> String {
        curves_csv(&self.profiles(reference_algo))
    }
}

/// Run `schedulers` over every tape of `ds` at penalty `u`.
///
/// `max_k` skips instances with more requested files than the cap (used to
/// keep exact-DP sweeps tractable in CI; `None` = run everything).
pub fn run_evaluation(
    ds: &Dataset,
    schedulers: &[Box<dyn Scheduler + Send + Sync>],
    u: u64,
    max_k: Option<usize>,
) -> EvalTable {
    let mut records = Vec::new();
    let names: Vec<String> = schedulers.iter().map(|s| s.name()).collect();
    for t in &ds.tapes {
        if let Some(cap) = max_k {
            if t.n_req() > cap {
                continue;
            }
        }
        let inst = t.instance(u).expect("dataset tapes are valid instances");
        let lb = virtual_lb(&inst);
        for s in schedulers {
            let start = Instant::now();
            let sched = s.schedule(&inst);
            let seconds = start.elapsed().as_secs_f64();
            let out = evaluate(&inst, &sched);
            records.push(EvalRecord {
                algorithm: s.name(),
                tape: t.tape.name.clone(),
                cost: out.cost,
                virtual_lb: lb,
                n_detours: sched.len(),
                seconds,
            });
        }
    }
    EvalTable { u, records, algorithms: names }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{generate_dataset, GeneratorConfig};
    use crate::sched::{Dp, Gs, NoDetour};

    fn small_ds() -> Dataset {
        // Shrink the marginals so DP runs fast in tests.
        generate_dataset(&GeneratorConfig {
            n_tapes: 6,
            nf: (30, 60.0, 70.0, 120),
            nreq: (5, 10.0, 12.0, 20),
            n: (10, 30.0, 40.0, 80),
            ..Default::default()
        })
    }

    fn algos() -> Vec<Box<dyn Scheduler + Send + Sync>> {
        vec![Box::new(NoDetour), Box::new(Gs), Box::new(Dp)]
    }

    #[test]
    fn evaluation_produces_full_grid() {
        let ds = small_ds();
        let table = run_evaluation(&ds, &algos(), 0, None);
        assert_eq!(table.records.len(), 3 * ds.tapes.len());
        // DP is the reference: zero overhead everywhere.
        for (c, r) in table.cost_pairs("DP", "DP") {
            assert_eq!(c, r);
        }
        // Everyone ≥ DP ≥ VirtualLB.
        for rec in &table.records {
            assert!(rec.cost >= rec.virtual_lb);
        }
        for algo in ["NoDetour", "GS"] {
            for (c, r) in table.cost_pairs(algo, "DP") {
                assert!(c >= r, "{algo}: {c} < {r}");
            }
        }
    }

    #[test]
    fn profiles_are_monotone_and_dp_reference_excluded() {
        let ds = small_ds();
        let table = run_evaluation(&ds, &algos(), 1000, None);
        let curves = table.profiles("DP");
        assert_eq!(curves.len(), 2);
        for c in &curves {
            for w in c.points.windows(2) {
                assert!(w[0].fraction <= w[1].fraction, "{}", c.algorithm);
            }
            let last = c.points.last().unwrap();
            assert!(last.fraction <= 1.0);
        }
    }

    #[test]
    fn max_k_filters_instances() {
        let ds = small_ds();
        let all = run_evaluation(&ds, &algos(), 0, None);
        let few = run_evaluation(&ds, &algos(), 0, Some(1));
        assert!(few.records.len() < all.records.len());
    }

    #[test]
    fn csv_outputs() {
        let ds = small_ds();
        let table = run_evaluation(&ds, &algos(), 0, None);
        assert!(table.records_csv().starts_with("algorithm,tape,"));
        assert!(table.profiles_csv("DP").starts_with("tau_pct,"));
        let times = table.median_times();
        assert_eq!(times.len(), 3);
    }
}
