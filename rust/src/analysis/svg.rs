//! Head-trajectory visualization — the Rust equivalent of the paper
//! artifact's `draw.py`: renders the `(time, position)` polyline of a
//! schedule as an SVG, with the requested files drawn as vertical bands
//! and service instants marked.

use crate::model::Instance;
use crate::sched::Detour;
use crate::sim::{evaluate, trajectory};

/// Render the trajectory of `detours` on `inst` as a standalone SVG.
///
/// Axes: x = position on tape (left → right), y = time (downwards), so the
/// head "descends" through the schedule like in the paper's Figures 1–2.
pub fn trajectory_svg(inst: &Instance, detours: &[Detour], title: &str) -> String {
    const W: f64 = 900.0;
    const H: f64 = 600.0;
    const MX: f64 = 60.0; // margins
    const MY: f64 = 50.0;

    let segs = trajectory::polyline(inst, detours);
    let out = evaluate(inst, detours);
    let t_max = segs.last().map(|s| s.t1).unwrap_or(1).max(1) as f64;
    let m = inst.tape_len().max(1) as f64;

    let sx = |pos: f64| MX + pos / m * (W - 2.0 * MX);
    let sy = |t: f64| MY + t / t_max * (H - 2.0 * MY);

    let mut svg = String::new();
    svg.push_str(&format!(
        r#"<svg xmlns="http://www.w3.org/2000/svg" width="{W}" height="{H}" viewBox="0 0 {W} {H}">"#
    ));
    svg.push('\n');
    svg.push_str(&format!(
        r#"<rect width="{W}" height="{H}" fill="white"/>
<text x="{}" y="24" font-family="sans-serif" font-size="16" text-anchor="middle">{}</text>
<text x="{}" y="{}" font-family="sans-serif" font-size="12" text-anchor="middle">position on tape →</text>
<text x="16" y="{}" font-family="sans-serif" font-size="12" transform="rotate(-90 16 {})" text-anchor="middle">← time</text>
"#,
        W / 2.0,
        xml_escape(title),
        W / 2.0,
        H - 12.0,
        H / 2.0,
        H / 2.0,
    ));

    // Requested files as vertical bands, labeled with multiplicity.
    for f in 0..inst.k() {
        let x0 = sx(inst.l(f) as f64);
        let x1 = sx(inst.r(f) as f64);
        svg.push_str(&format!(
            r##"<rect x="{:.1}" y="{MY}" width="{:.2}" height="{:.1}" fill="#9ecae1" fill-opacity="0.35"/>
<text x="{:.1}" y="{:.1}" font-family="sans-serif" font-size="10" text-anchor="middle">f{} ×{}</text>
"##,
            x0,
            (x1 - x0).max(1.0),
            H - 2.0 * MY,
            (x0 + x1) / 2.0,
            MY - 6.0,
            f,
            inst.x(f)
        ));
    }

    // The trajectory polyline (U-turn dwells appear as vertical steps).
    let mut path = String::new();
    for (i, s) in segs.iter().enumerate() {
        if i == 0 {
            path.push_str(&format!("M {:.1} {:.1} ", sx(s.from as f64), sy(s.t0 as f64)));
        }
        path.push_str(&format!("L {:.1} {:.1} ", sx(s.to as f64), sy(s.t1 as f64)));
    }
    svg.push_str(&format!(
        r##"<path d="{path}" fill="none" stroke="#d62728" stroke-width="1.8"/>
"##
    ));

    // Service instants: a dot where each file's right end is passed.
    for f in 0..inst.k() {
        svg.push_str(&format!(
            r##"<circle cx="{:.1}" cy="{:.1}" r="3.5" fill="#2ca02c"><title>f{} served at t={}</title></circle>
"##,
            sx(inst.r(f) as f64),
            sy(out.service[f] as f64),
            f,
            out.service[f]
        ));
    }

    svg.push_str("</svg>\n");
    svg
}

fn xml_escape(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ReqFile;
    use crate::sched::{Dp, Scheduler};

    fn inst() -> Instance {
        Instance::new(
            100,
            3,
            vec![ReqFile { l: 10, r: 20, x: 2 }, ReqFile { l: 60, r: 70, x: 5 }],
        )
        .unwrap()
    }

    #[test]
    fn svg_is_well_formed_and_complete() {
        let i = inst();
        let sched = Dp.schedule(&i);
        let svg = trajectory_svg(&i, &sched, "test <schedule>");
        assert!(svg.starts_with("<svg"));
        assert!(svg.trim_end().ends_with("</svg>"));
        assert!(svg.contains("&lt;schedule&gt;"), "title must be escaped");
        // One band + one service dot per requested file.
        assert_eq!(svg.matches("fill-opacity").count(), i.k());
        assert_eq!(svg.matches("<circle").count(), i.k());
        assert_eq!(svg.matches("<path").count(), 1);
    }

    #[test]
    fn empty_schedule_still_renders() {
        let svg = trajectory_svg(&inst(), &[], "no detours");
        assert!(svg.contains("<path"));
    }
}
