//! Dolan–Moré performance profiles (§5.3, after [13]).
//!
//! For each algorithm and each overhead threshold `τ` (in percent), the
//! profile value is the fraction of instances on which the algorithm's cost
//! is at most `(1 + τ/100) · cost(DP)`. The higher the curve, the better.

/// One `(τ %, fraction)` point of a profile curve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProfilePoint {
    pub tau_pct: f64,
    pub fraction: f64,
}

/// A full profile curve for one algorithm.
#[derive(Debug, Clone)]
pub struct ProfileCurve {
    pub algorithm: String,
    pub points: Vec<ProfilePoint>,
}

impl ProfileCurve {
    /// Profile value at threshold `tau_pct` (step function, right-continuous).
    pub fn at(&self, tau_pct: f64) -> f64 {
        self.points
            .iter()
            .rev()
            .find(|p| p.tau_pct <= tau_pct)
            .map_or(0.0, |p| p.fraction)
    }

    /// Area under the curve on `[0, max_tau]` (useful as a scalar summary;
    /// higher is better).
    pub fn auc(&self, max_tau: f64) -> f64 {
        let mut area = 0.0;
        let mut prev_tau = 0.0;
        let mut prev_val = 0.0;
        for p in &self.points {
            if p.tau_pct > max_tau {
                break;
            }
            area += prev_val * (p.tau_pct - prev_tau);
            prev_tau = p.tau_pct;
            prev_val = p.fraction;
        }
        area + prev_val * (max_tau - prev_tau)
    }
}

/// Build the performance-profile curve of one algorithm from per-instance
/// `(algorithm cost, reference cost)` pairs, sampled at `taus` (percent).
///
/// `reference` is the optimum (DP); costs may be any totally ordered scalar
/// as long as `cost ≥ reference > 0`.
pub fn performance_profile(
    algorithm: &str,
    costs: &[(i128, i128)],
    taus: &[f64],
) -> ProfileCurve {
    assert!(!costs.is_empty(), "need at least one instance");
    let n = costs.len() as f64;
    // Overhead of each instance, in percent.
    let mut overheads: Vec<f64> = costs
        .iter()
        .map(|&(c, r)| {
            assert!(r > 0, "reference cost must be positive");
            debug_assert!(c >= r, "algorithm beats the exact reference: {c} < {r}");
            (c - r) as f64 / r as f64 * 100.0
        })
        .collect();
    overheads.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let points = taus
        .iter()
        .map(|&tau| {
            // fraction of instances with overhead ≤ tau (+ tiny f64 slack)
            let cnt = overheads.partition_point(|&o| o <= tau + 1e-12);
            ProfilePoint { tau_pct: tau, fraction: cnt as f64 / n }
        })
        .collect();
    ProfileCurve { algorithm: algorithm.to_string(), points }
}

/// The τ grid used for Figures 14–16: dense near 0, log-spread to 50 %.
pub fn paper_tau_grid() -> Vec<f64> {
    let mut taus = vec![0.0];
    // 0.1 … 1.0 by 0.1; 1.25 … 10 by 0.25; 11 … 50 by 1.
    for i in 1..=10 {
        taus.push(i as f64 * 0.1);
    }
    let mut t = 1.25;
    while t <= 10.0 {
        taus.push(t);
        t += 0.25;
    }
    for i in 11..=50 {
        taus.push(i as f64);
    }
    taus
}

/// Render a set of curves as CSV: `tau,algo1,algo2,…`.
pub fn curves_csv(curves: &[ProfileCurve]) -> String {
    assert!(!curves.is_empty());
    let mut out = String::from("tau_pct");
    for c in curves {
        out.push(',');
        out.push_str(&c.algorithm);
    }
    out.push('\n');
    let n_pts = curves[0].points.len();
    for i in 0..n_pts {
        out.push_str(&format!("{:.2}", curves[0].points[i].tau_pct));
        for c in curves {
            out.push_str(&format!(",{:.4}", c.points[i].fraction));
        }
        out.push('\n');
    }
    out
}

/// Render curves as a compact ASCII chart (for terminal output).
pub fn curves_ascii(curves: &[ProfileCurve], taus: &[f64]) -> String {
    let mut out = String::new();
    out.push_str(&format!("{:<12}", "algorithm"));
    for &t in taus {
        out.push_str(&format!(" τ≤{:>4}%", t));
    }
    out.push('\n');
    for c in curves {
        out.push_str(&format!("{:<12}", c.algorithm));
        for &t in taus {
            out.push_str(&format!(" {:>6.1}%", c.at(t) * 100.0));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_fractions() {
        // 4 instances: overheads 0 %, 0 %, 10 %, 50 %.
        let costs = vec![(100, 100), (200, 200), (110, 100), (300, 200)];
        let cur = performance_profile("X", &costs, &[0.0, 5.0, 10.0, 50.0, 100.0]);
        let fr: Vec<f64> = cur.points.iter().map(|p| p.fraction).collect();
        assert_eq!(fr, vec![0.5, 0.5, 0.75, 1.0, 1.0]);
    }

    #[test]
    fn at_is_right_continuous_step() {
        let costs = vec![(110, 100)];
        let cur = performance_profile("X", &costs, &[0.0, 10.0]);
        assert_eq!(cur.at(0.0), 0.0);
        assert_eq!(cur.at(9.9), 0.0); // sampled grid: no point between 0 and 10
        assert_eq!(cur.at(10.0), 1.0);
        assert_eq!(cur.at(99.0), 1.0);
    }

    #[test]
    fn auc_orders_better_algorithms_higher() {
        let exact = performance_profile("exact", &[(100, 100), (200, 200)], &[0.0, 10.0]);
        let sloppy = performance_profile("sloppy", &[(150, 100), (300, 200)], &[0.0, 10.0]);
        assert!(exact.auc(10.0) > sloppy.auc(10.0));
        assert_eq!(exact.auc(10.0), 10.0); // 100 % everywhere
    }

    #[test]
    fn csv_shape() {
        let a = performance_profile("A", &[(100, 100)], &[0.0, 1.0]);
        let b = performance_profile("B", &[(101, 100)], &[0.0, 1.0]);
        let csv = curves_csv(&[a, b]);
        let mut lines = csv.lines();
        assert_eq!(lines.next(), Some("tau_pct,A,B"));
        assert_eq!(lines.next(), Some("0.00,1.0000,0.0000"));
        assert_eq!(lines.next(), Some("1.00,1.0000,1.0000"));
    }

    #[test]
    fn paper_grid_is_sorted_and_dense_near_zero() {
        let g = paper_tau_grid();
        assert!(g.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(g[0], 0.0);
        assert!(g.iter().filter(|&&t| t <= 1.0).count() >= 10);
        assert_eq!(*g.last().unwrap(), 50.0);
    }
}
