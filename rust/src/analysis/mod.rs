//! Evaluation harness: per-instance algorithm costs, Dolan–Moré performance
//! profiles (the §5.3 methodology) and CSV/report writers for Figures 14–16.

pub mod profile;
pub mod report;
pub mod svg;

pub use profile::{performance_profile, ProfileCurve, ProfilePoint};
pub use report::{run_evaluation, EvalRecord, EvalTable};
pub use svg::trajectory_svg;
