//! Evaluation harness: per-instance algorithm costs, Dolan–Moré performance
//! profiles (the §5.3 methodology), CSV/report writers for Figures 14–16,
//! the cross-policy QoS comparison for replay runs, and the shard-imbalance
//! summary for sharded (multi-library) replays.

pub mod profile;
pub mod report;
pub mod svg;

pub use profile::{performance_profile, ProfileCurve, ProfilePoint};
pub use report::{
    cartridge_summary, mount_summary, qos_comparison, run_evaluation,
    run_evaluation_with_threads, shard_summary, EvalRecord, EvalTable,
};
pub use svg::trajectory_svg;
