//! Evaluation harness: per-instance algorithm costs, Dolan–Moré performance
//! profiles (the §5.3 methodology), CSV/report writers for Figures 14–16,
//! and the cross-policy QoS comparison for replay runs.

pub mod profile;
pub mod report;
pub mod svg;

pub use profile::{performance_profile, ProfileCurve, ProfilePoint};
pub use report::{qos_comparison, run_evaluation, EvalRecord, EvalTable};
pub use svg::trajectory_svg;
