//! Physical tape description: the full sequence of files (requested or not)
//! as stored in the mass-storage catalog. This is the on-tape view used by
//! the dataset loader and the library simulator; scheduling algorithms work
//! on the compacted [`super::Instance`] view (requested files only).

/// A file (or aggregate) extent on the tape, `[left, left + size)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FileExtent {
    /// Distance from the left end of the tape to the left of the file.
    pub left: u64,
    /// File size in bytes.
    pub size: u64,
}

impl FileExtent {
    /// Right end of the file.
    pub fn right(&self) -> u64 {
        self.left + self.size
    }
}

/// A full tape: an ordered, contiguous partition of `[0, len)` into files.
///
/// Mirrors the dataset's `tapes/TAPEXXX.txt` description (segments with
/// cumulative positions and sizes, indexed from 1 for the leftmost file).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Tape {
    /// Tape identifier (e.g. `TAPE042`).
    pub name: String,
    /// Files left-to-right. `files[0].left == 0` and files are contiguous.
    pub files: Vec<FileExtent>,
}

impl Tape {
    /// Build a tape from consecutive file sizes (files are contiguous,
    /// starting at position 0), as in the dataset's `segment_size` column.
    pub fn from_sizes(name: impl Into<String>, sizes: &[u64]) -> Tape {
        let mut files = Vec::with_capacity(sizes.len());
        let mut pos = 0u64;
        for &s in sizes {
            files.push(FileExtent { left: pos, size: s });
            pos += s;
        }
        Tape { name: name.into(), files }
    }

    /// Total tape length `m` (right end of the last file).
    pub fn len(&self) -> u64 {
        self.files.last().map(|f| f.right()).unwrap_or(0)
    }

    /// Number of files `n_f` on the tape.
    pub fn n_files(&self) -> usize {
        self.files.len()
    }

    pub fn is_empty(&self) -> bool {
        self.files.is_empty()
    }

    /// Average file ("segment") size, used to derive the paper's U values.
    pub fn mean_file_size(&self) -> f64 {
        if self.files.is_empty() {
            return 0.0;
        }
        self.len() as f64 / self.files.len() as f64
    }

    /// Coefficient of variation of file sizes (stddev / mean), as a fraction.
    pub fn file_size_cv(&self) -> f64 {
        if self.files.is_empty() {
            return 0.0;
        }
        let mean = self.mean_file_size();
        if mean == 0.0 {
            return 0.0;
        }
        let var = self
            .files
            .iter()
            .map(|f| {
                let d = f.size as f64 - mean;
                d * d
            })
            .sum::<f64>()
            / self.files.len() as f64;
        var.sqrt() / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_sizes_builds_contiguous_extents() {
        let t = Tape::from_sizes("T", &[5, 10, 3]);
        assert_eq!(t.n_files(), 3);
        assert_eq!(t.files[0], FileExtent { left: 0, size: 5 });
        assert_eq!(t.files[1], FileExtent { left: 5, size: 10 });
        assert_eq!(t.files[2], FileExtent { left: 15, size: 3 });
        assert_eq!(t.len(), 18);
    }

    #[test]
    fn stats() {
        let t = Tape::from_sizes("T", &[10, 10, 10]);
        assert_eq!(t.mean_file_size(), 10.0);
        assert_eq!(t.file_size_cv(), 0.0);
        let t2 = Tape::from_sizes("T2", &[5, 15]);
        assert!((t2.file_size_cv() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_tape() {
        let t = Tape::from_sizes("E", &[]);
        assert_eq!(t.len(), 0);
        assert!(t.is_empty());
        assert_eq!(t.mean_file_size(), 0.0);
    }
}
