//! Compacted LTSP instance: the requested files only, plus tape length and
//! U-turn penalty. All scheduling algorithms of the paper consume only
//! `(ℓ(f), r(f), x(f))` of requested files, `m` and `U` — gaps between
//! requested files (unrequested data) enter through `ℓ(b) − r(left(b))`.

use super::{Cost, Tape};

/// A requested file: extent `[l, r)` and request multiplicity `x ≥ 1`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReqFile {
    pub l: u64,
    pub r: u64,
    pub x: u64,
}

/// Errors raised when assembling an [`Instance`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InstanceError {
    Empty,
    BadExtent(usize),
    ZeroRequests(usize),
    Overlap(usize, usize),
    PastEnd(usize),
}

impl std::fmt::Display for InstanceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InstanceError::Empty => {
                write!(f, "instance must contain at least one requested file")
            }
            InstanceError::BadExtent(i) => write!(f, "file {i} has zero or negative extent"),
            InstanceError::ZeroRequests(i) => write!(f, "file {i} has zero requests"),
            InstanceError::Overlap(i, j) => {
                write!(f, "files {i} and {j} overlap or are out of order")
            }
            InstanceError::PastEnd(i) => write!(f, "file {i} extends past the tape end"),
        }
    }
}

impl std::error::Error for InstanceError {}

/// An LTSP instance over the requested files, indexed `0..k` left-to-right.
///
/// Precomputes the prefix sums used throughout the algorithms:
/// `n_ℓ(i)` (requests strictly left of file `i`), `Σ ℓ(f)·x(f)` and
/// `Σ x(f)` prefixes for SimpleDP's closed-form detour cost.
#[derive(Debug, Clone)]
pub struct Instance {
    tape_len: u64,
    u: u64,
    files: Vec<ReqFile>,
    /// `nl[i]` = number of requests on files strictly left of file `i`.
    /// `nl[k]` = total number of requests `n`.
    nl: Vec<u64>,
    /// `lx[i+1]` = Σ_{j ≤ i} ℓ(j)·x(j) (so `lx[0] = 0`).
    lx: Vec<i128>,
}

impl Instance {
    /// Build and validate an instance. Files must be sorted left-to-right,
    /// disjoint, non-empty, with `x ≥ 1`, and fit within `[0, tape_len]`.
    pub fn new(tape_len: u64, u: u64, files: Vec<ReqFile>) -> Result<Instance, InstanceError> {
        if files.is_empty() {
            return Err(InstanceError::Empty);
        }
        for (i, f) in files.iter().enumerate() {
            if f.r <= f.l {
                return Err(InstanceError::BadExtent(i));
            }
            if f.x == 0 {
                return Err(InstanceError::ZeroRequests(i));
            }
            if f.r > tape_len {
                return Err(InstanceError::PastEnd(i));
            }
            if i > 0 && files[i - 1].r > f.l {
                return Err(InstanceError::Overlap(i - 1, i));
            }
        }
        let mut nl = Vec::with_capacity(files.len() + 1);
        let mut lx = Vec::with_capacity(files.len() + 1);
        nl.push(0);
        lx.push(0);
        for f in &files {
            nl.push(nl.last().unwrap() + f.x);
            lx.push(lx.last().unwrap() + f.l as i128 * f.x as i128);
        }
        Ok(Instance { tape_len, u, files, nl, lx })
    }

    /// Build an instance from a full [`Tape`] and `(file index, multiplicity)`
    /// request pairs (indices into `tape.files`, any order, merged if dup).
    pub fn from_tape(
        tape: &Tape,
        requests: &[(usize, u64)],
        u: u64,
    ) -> Result<Instance, InstanceError> {
        let mut counts = std::collections::BTreeMap::new();
        for &(idx, x) in requests {
            *counts.entry(idx).or_insert(0u64) += x;
        }
        let files = counts
            .into_iter()
            .map(|(idx, x)| {
                let f = tape.files[idx];
                ReqFile { l: f.left, r: f.right(), x }
            })
            .collect();
        Instance::new(tape.len(), u, files)
    }

    /// Number of distinct requested files `n_req` (written `k` in the code).
    #[inline]
    pub fn k(&self) -> usize {
        self.files.len()
    }

    /// Total number of requests `n`.
    #[inline]
    pub fn n(&self) -> u64 {
        *self.nl.last().unwrap()
    }

    /// Tape length `m`.
    #[inline]
    pub fn tape_len(&self) -> u64 {
        self.tape_len
    }

    /// U-turn penalty.
    #[inline]
    pub fn u(&self) -> u64 {
        self.u
    }

    /// Return a copy of this instance with a different U-turn penalty.
    pub fn with_u(&self, u: u64) -> Instance {
        let mut inst = self.clone();
        inst.u = u;
        inst
    }

    /// Left end `ℓ(i)` of requested file `i`.
    #[inline]
    pub fn l(&self, i: usize) -> u64 {
        self.files[i].l
    }

    /// Right end `r(i)`.
    #[inline]
    pub fn r(&self, i: usize) -> u64 {
        self.files[i].r
    }

    /// Size `s(i) = r(i) − ℓ(i)`.
    #[inline]
    pub fn s(&self, i: usize) -> u64 {
        self.files[i].r - self.files[i].l
    }

    /// Multiplicity `x(i)`.
    #[inline]
    pub fn x(&self, i: usize) -> u64 {
        self.files[i].x
    }

    /// `n_ℓ(i)`: number of requests on files strictly left of file `i`.
    #[inline]
    pub fn nl(&self, i: usize) -> u64 {
        self.nl[i]
    }

    /// Prefix `Σ_{j < i} ℓ(j)·x(j)` (note: exclusive, `lx_prefix(0) = 0`).
    #[inline]
    pub fn lx_prefix(&self, i: usize) -> i128 {
        self.lx[i]
    }

    /// `Σ_{c < f ≤ b} (ℓ(f) − ℓ(c))·x(f)` — the SimpleDP in-detour term,
    /// computed from prefix sums in O(1).
    pub fn in_detour_span_cost(&self, c: usize, b: usize) -> Cost {
        debug_assert!(c <= b);
        let sum_lx = self.lx[b + 1] - self.lx[c + 1];
        let sum_x = (self.nl[b + 1] - self.nl[c + 1]) as i128;
        sum_lx - self.l(c) as i128 * sum_x
    }

    /// The requested files slice.
    pub fn files(&self) -> &[ReqFile] {
        &self.files
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inst3() -> Instance {
        Instance::new(
            100,
            2,
            vec![
                ReqFile { l: 0, r: 10, x: 1 },
                ReqFile { l: 20, r: 25, x: 3 },
                ReqFile { l: 40, r: 70, x: 2 },
            ],
        )
        .unwrap()
    }

    #[test]
    fn accessors_and_prefixes() {
        let i = inst3();
        assert_eq!(i.k(), 3);
        assert_eq!(i.n(), 6);
        assert_eq!(i.s(2), 30);
        assert_eq!(i.nl(0), 0);
        assert_eq!(i.nl(1), 1);
        assert_eq!(i.nl(2), 4);
        assert_eq!(i.lx_prefix(3), 0 + 20 * 3 + 40 * 2);
    }

    #[test]
    fn in_detour_span_cost_matches_naive() {
        let i = inst3();
        // c = 0, b = 2: Σ_{0<f≤2} (ℓ(f) − ℓ(0))·x(f) = 20*3 + 40*2 = 140
        assert_eq!(i.in_detour_span_cost(0, 2), 140);
        // c = 1, b = 2: (40-20)*2 = 40
        assert_eq!(i.in_detour_span_cost(1, 2), 40);
        // c = b: empty sum
        assert_eq!(i.in_detour_span_cost(2, 2), 0);
    }

    #[test]
    fn validation_errors() {
        assert_eq!(Instance::new(10, 0, vec![]).unwrap_err(), InstanceError::Empty);
        assert_eq!(
            Instance::new(10, 0, vec![ReqFile { l: 5, r: 5, x: 1 }]).unwrap_err(),
            InstanceError::BadExtent(0)
        );
        assert_eq!(
            Instance::new(10, 0, vec![ReqFile { l: 0, r: 5, x: 0 }]).unwrap_err(),
            InstanceError::ZeroRequests(0)
        );
        assert_eq!(
            Instance::new(
                10,
                0,
                vec![ReqFile { l: 0, r: 6, x: 1 }, ReqFile { l: 5, r: 8, x: 1 }]
            )
            .unwrap_err(),
            InstanceError::Overlap(0, 1)
        );
        assert_eq!(
            Instance::new(10, 0, vec![ReqFile { l: 0, r: 11, x: 1 }]).unwrap_err(),
            InstanceError::PastEnd(0)
        );
    }

    #[test]
    fn from_tape_merges_duplicates() {
        let t = Tape::from_sizes("T", &[10, 10, 10]);
        let inst = Instance::from_tape(&t, &[(2, 1), (0, 2), (2, 3)], 5).unwrap();
        assert_eq!(inst.k(), 2);
        assert_eq!(inst.x(0), 2);
        assert_eq!(inst.x(1), 4);
        assert_eq!(inst.l(1), 20);
        assert_eq!(inst.tape_len(), 30);
    }
}
