//! Problem model for the Linear Tape Scheduling Problem (LTSP).
//!
//! The model follows §3 of the paper: a linear tape of length `m` divided in
//! disjoint files, a multiset of read requests over those files, a reading
//! head starting at the right end of the tape, and a U-turn penalty `U`.
//!
//! Positions and sizes are in **bytes** (`u64`); service times and costs are
//! exact **`i128`** values (byte-resolution positions up to 20 TB multiplied
//! by up to ~15 k requests overflow `i64` products).

pub mod adversarial;
mod instance;
mod tape;

pub use instance::{Instance, InstanceError, ReqFile};
pub use tape::{FileExtent, Tape};

/// Exact cost / time type used across the crate.
pub type Cost = i128;

/// The `VirtualLB` lower bound of §3: `Σ_f x(f) · (m − ℓ(f) + s(f) + U)`,
/// i.e. the cost if each request were served by its own dedicated head.
pub fn virtual_lb(inst: &Instance) -> Cost {
    let m = inst.tape_len() as Cost;
    let u = inst.u() as Cost;
    (0..inst.k())
        .map(|i| {
            inst.x(i) as Cost * (m - inst.l(i) as Cost + inst.s(i) as Cost + u)
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_lb_single_file() {
        // One file [10, 20) on a tape of length 100, 3 requests, U = 7.
        let inst = Instance::new(100, 7, vec![ReqFile { l: 10, r: 20, x: 3 }]).unwrap();
        // 3 * (100 - 10 + 10 + 7) = 3 * 107 = 321
        assert_eq!(virtual_lb(&inst), 321);
    }

    #[test]
    fn virtual_lb_two_files() {
        let inst = Instance::new(
            100,
            0,
            vec![ReqFile { l: 0, r: 5, x: 1 }, ReqFile { l: 50, r: 60, x: 2 }],
        )
        .unwrap();
        // f1: 1*(100-0+5+0)=105 ; f2: 2*(100-50+10+0)=120
        assert_eq!(virtual_lb(&inst), 225);
    }
}
