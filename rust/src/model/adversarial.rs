//! The paper's adversarial instance families (§4.5 and Lemma 2), used to
//! exhibit approximation-ratio lower bounds experimentally.

use super::{Instance, ReqFile};

/// §4.5's LogDP worst case: `z` requested files; a small non-urgent file on
/// the far left, a contiguous cluster far right whose leftmost member is
/// very urgent (`x = z²`) and whose rightmost member is large (`s = z²`)
/// and moderately urgent (`x = z`). The optimal solution uses one long
/// detour `(f₂, f_z)` — out of reach once detour spans are capped — so
/// LogDP's ratio tends to 3 (with `U = 0`) as `z` grows.
pub fn logdp_worst_case(z: u64) -> Instance {
    assert!(z >= 4, "the construction needs z ≥ 4");
    let base = 2 * z * z * z;
    let mut files = vec![ReqFile { l: 0, r: 1, x: 1 }];
    // z − 1 contiguous files starting at 2z³: unit size except the last.
    for i in 0..z - 1 {
        let l = base + i;
        let (r, x) = if i == z - 2 {
            (l + z * z, z) // rightmost: large, moderately urgent
        } else if i == 0 {
            (l + 1, z * z) // leftmost of the cluster: very urgent
        } else {
            (l + 1, 1)
        };
        files.push(ReqFile { l, r, x });
    }
    let m = files.last().unwrap().r;
    Instance::new(m, 0, files).expect("construction is valid")
}

/// Lemma 2's 5/3 lower-bound instance for SimpleDP: four files where the
/// best solution reads `f₃` alone, then `f₂` and `f₄` in one *intertwined*
/// detour over the already-read `f₃` — exactly what SimpleDP's disjoint
/// detours cannot express. SimpleDP/OPT → 5/3 as `z` grows.
pub fn simpledp_five_thirds(z: u64) -> Instance {
    assert!(z >= 3);
    let f1 = ReqFile { l: 0, r: 1, x: 1 };
    let l2 = 3 * z * z;
    let f2 = ReqFile { l: l2, r: l2 + 1, x: z * z };
    let l3 = l2 + z;
    let f3 = ReqFile { l: l3, r: l3 + 1, x: z * z };
    let f4 = ReqFile { l: l3 + 1, r: l3 + 1 + z, x: z };
    let m = f4.r;
    Instance::new(m, 0, vec![f1, f2, f3, f4]).expect("construction is valid")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{Dp, Gs, LogDp, Scheduler, SimpleDp};
    use crate::sim::evaluate;

    #[test]
    fn simpledp_ratio_tends_to_five_thirds() {
        let mut last = 0.0;
        for z in [5u64, 10, 20, 40] {
            let inst = simpledp_five_thirds(z);
            let opt = evaluate(&inst, &Dp.schedule(&inst)).cost;
            let sdp = evaluate(&inst, &SimpleDp.schedule(&inst)).cost;
            let ratio = sdp as f64 / opt as f64;
            assert!(ratio > 1.0, "SimpleDP must be suboptimal here (z={z})");
            assert!(ratio <= 3.0 + 1e-9, "Lemma 2 upper bound (z={z})");
            last = ratio;
        }
        assert!(
            (last - 5.0 / 3.0).abs() < 0.1,
            "ratio at z=40 should approach 5/3, got {last}"
        );
    }

    #[test]
    fn optimal_uses_the_intertwined_detour() {
        let inst = simpledp_five_thirds(20);
        let sched = Dp.schedule(&inst);
        // The signature move: a detour covering f2..f4 plus a nested/earlier
        // one on f3 alone — i.e. detours are NOT pairwise disjoint.
        let mut s = sched.clone();
        s.sort();
        let disjoint = s.windows(2).all(|w| w[0].b < w[1].a);
        assert!(!disjoint, "expected intertwined detours, got {sched:?}");
    }

    #[test]
    fn logdp_worst_case_ratio_grows() {
        let mut prev = 1.0;
        for z in [8u64, 16, 24] {
            let inst = logdp_worst_case(z);
            let opt = evaluate(&inst, &Dp.schedule(&inst)).cost;
            let log1 = evaluate(&inst, &LogDp::new(1.0).schedule(&inst)).cost;
            let gs = evaluate(&inst, &Gs.schedule(&inst)).cost;
            let ratio = log1 as f64 / opt as f64;
            assert!(ratio >= prev - 0.05, "ratio should grow with z, got {ratio} at z={z}");
            assert!(gs >= opt);
            prev = ratio;
        }
        assert!(prev > 1.5, "LogDP(1) ratio at z=24 should exceed 1.5, got {prev}");
    }

    #[test]
    fn constructions_scale_consistently() {
        for z in [4u64, 7, 33] {
            let a = logdp_worst_case(z);
            assert_eq!(a.k() as u64, z);
            assert_eq!(a.n(), 1 + z * z + (z - 3) + z);
            let b = simpledp_five_thirds(z);
            assert_eq!(b.k(), 4);
            assert_eq!(b.n(), 1 + 2 * z * z + z);
        }
    }
}
