//! # tapesched — Linear Tape Scheduling
//!
//! Production-shaped reproduction of *“An Exact Algorithm for the Linear
//! Tape Scheduling Problem”* (Honoré, Simon, Suter — 2021): exact and
//! heuristic schedulers minimizing the **average service time** of read
//! requests on a linear magnetic tape, plus the surrounding mass-storage
//! machinery: a ground-truth head simulator, a robotic-library serving
//! runtime, a dataset pipeline, pluggable SimpleDP evaluation backends
//! (optionally XLA-accelerated) and the full evaluation harness of the
//! paper.
//!
//! ## Quick start
//!
//! ```
//! use tapesched::model::{Instance, ReqFile};
//! use tapesched::sched::{Dp, Scheduler};
//! use tapesched::sim::evaluate;
//!
//! // A 100-unit tape; two requested files, the right one is urgent.
//! let inst = Instance::new(100, 5, vec![
//!     ReqFile { l: 10, r: 20, x: 1 },
//!     ReqFile { l: 60, r: 70, x: 40 },
//! ]).unwrap();
//!
//! let schedule = Dp.schedule(&inst);          // exact optimum
//! let outcome  = evaluate(&inst, &schedule);  // ground-truth service times
//! assert!(outcome.cost <= evaluate(&inst, &[]).cost);
//! ```
//!
//! ## Layout
//!
//! - [`model`] — tapes, requests, instances, exact cost arithmetic.
//! - [`sched`] — the paper's nine algorithms behind one [`sched::Scheduler`] trait.
//! - [`sim`] — head-trajectory ground truth + robotic library simulator.
//! - [`resources`] — the shared tape/drive/arm resource layer: cartridge
//!   exclusivity ledger, drive-pool state machine, robot-arm pool and
//!   timeline — one source of truth under both serving paths.
//! - [`coordinator`] — multi-threaded request-serving service (one library).
//! - [`cluster`] — multi-library sharding: consistent-hash routing over N
//!   coordinators, per-shard backpressure, cluster metrics rollup.
//! - [`net`] — the networked cluster: a dependency-free length-prefixed
//!   binary protocol over `TcpStream`, the coordinator/worker processes
//!   speaking it, and the `RequestSink` client that drives a remote fleet.
//! - [`obs`] — observability: the request-lifecycle span recorder shared
//!   by the replay engine and the live coordinator, span analysis
//!   (`tapesched spans`), and the Prometheus-style exposition endpoint.
//! - [`replay`] — virtual-time workload replay: arrival models, the
//!   discrete-event engine, and QoS percentile reports.
//! - [`runtime`] — pluggable SimpleDP backends: pure-Rust dense (default)
//!   plus the PJRT/XLA engine behind the off-by-default `xla` feature.
//! - [`dataset`] — IN2P3-format loader, calibrated synthetic generator, stats.
//! - [`analysis`] — performance profiles (Dolan–Moré) and CSV reports.
//! - [`bench`] — the in-crate benchmark framework used by `cargo bench`.
//! - [`audit`] — the in-crate static-analysis pass (`tapesched audit`)
//!   enforcing determinism, wire-parity, panic-policy, and accounting
//!   invariants over these very sources.

#![forbid(unsafe_code)]
#![deny(rust_2018_idioms)]

pub mod analysis;
pub mod audit;
pub mod bench;
pub mod cli;
pub mod cluster;
pub mod coordinator;
pub mod dataset;
pub mod model;
pub mod net;
pub mod obs;
pub mod replay;
pub mod resources;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod testkit;
pub mod util;
