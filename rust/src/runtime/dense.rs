//! The always-available pure-Rust SimpleDP backend.
//!
//! Wraps the exact `i128` dense wavefront of
//! [`crate::sched::simpledp_dense`]: the same `(k × (n+1))` table the AOT
//! artifacts compute, evaluated bottom-up in Rust. Memory and time are
//! Θ(k·n) and Θ(k²·n) — identical asymptotics to the accelerated path,
//! with no artifact or feature requirements.
//!
//! Dispatch-path allocation discipline: each worker thread keeps one
//! [`DenseScratch`] (thread-local, the backend itself stays a stateless
//! `Copy` type shared through `Arc`), so coordinator batches and replay
//! dispatches on hot tapes reuse the Θ(k·n) buffers instead of allocating
//! them anew per call; cost-only queries additionally skip the choice
//! table entirely.
//!
//! ## Result cache
//!
//! Hot tapes frequently see *identical* batches back to back (the same
//! popular files re-requested inside one window shape), and the dense
//! wavefront is Θ(k²·n) per evaluation. A small per-thread memo keyed on
//! the instance — tape geometry, `U`, and the full requested-file multiset
//! — lets repeated identical batches skip the wavefront entirely. The key
//! is a 128-bit fingerprint (two independent FNV-1a streams over every
//! `(ℓ, r, x)` plus `m` and `U`, each finished with `fmix64`): a false
//! collision needs ~2⁶⁴ distinct batches on one thread (birthday bound),
//! far beyond any replay, and the cache is cleared wholesale at
//! [`CACHE_CAP`] entries so memory stays bounded. Process-wide hit/miss
//! counters are exported via [`dense_cache_stats`] for the serving
//! metrics.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::model::{Cost, Instance};
use crate::sched::simpledp_dense::{dense_cost_into, dense_solve_into, DenseScratch};
use crate::sched::Schedule;
use crate::util::hash::{fmix64, FxHashMap};

use super::SimpleDpBackend;

/// Entries per thread-local result cache before it is cleared wholesale.
const CACHE_CAP: usize = 1024;

static CACHE_HITS: AtomicU64 = AtomicU64::new(0);
static CACHE_MISSES: AtomicU64 = AtomicU64::new(0);

/// Process-wide dense result-cache counters: `(hits, misses)`, summed over
/// every thread since process start. A hit means a dispatched batch
/// skipped the Θ(k²·n) wavefront entirely.
pub fn dense_cache_stats() -> (u64, u64) {
    (CACHE_HITS.load(Ordering::Relaxed), CACHE_MISSES.load(Ordering::Relaxed))
}

/// 128-bit instance fingerprint (plus the exact `k`/`n` as a free sanity
/// dimension). See the module docs for the collision argument.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct InstKey {
    h1: u64,
    h2: u64,
    k: usize,
    n: u64,
}

fn fingerprint(inst: &Instance) -> InstKey {
    let mut h1: u64 = 0xcbf2_9ce4_8422_2325;
    let mut h2: u64 = 0x6c62_272e_07bb_0142;
    let mut eat = |v: u64| {
        h1 = (h1 ^ v).wrapping_mul(0x0000_0100_0000_01b3);
        h2 = (h2 ^ v.rotate_left(32)).wrapping_mul(0x0000_0100_0000_01b3);
    };
    eat(inst.tape_len());
    eat(inst.u());
    for f in inst.files() {
        eat(f.l);
        eat(f.r);
        eat(f.x);
    }
    InstKey { h1: fmix64(h1), h2: fmix64(h2), k: inst.k(), n: inst.n() }
}

#[derive(Debug, Clone)]
struct CacheEntry {
    cost: Cost,
    /// `None` until a schedule is first requested (cost-only queries stay
    /// cheap: no choice table, no reconstruction).
    schedule: Option<Schedule>,
}

thread_local! {
    static SCRATCH: RefCell<DenseScratch> = RefCell::new(DenseScratch::default());
    static CACHE: RefCell<FxHashMap<InstKey, CacheEntry>> =
        RefCell::new(FxHashMap::default());
}

fn cache_insert(key: InstKey, entry: CacheEntry) {
    CACHE.with(|c| {
        let mut c = c.borrow_mut();
        if c.len() >= CACHE_CAP {
            c.clear();
        }
        c.insert(key, entry);
    });
}

/// Pure-Rust dense SimpleDP backend (the default).
#[derive(Debug, Clone, Copy, Default)]
pub struct DenseBackend;

impl SimpleDpBackend for DenseBackend {
    fn id(&self) -> &'static str {
        "dense"
    }

    fn opt_cost(&self, inst: &Instance) -> Cost {
        let key = fingerprint(inst);
        if let Some(cost) = CACHE.with(|c| c.borrow().get(&key).map(|e| e.cost)) {
            CACHE_HITS.fetch_add(1, Ordering::Relaxed);
            return cost;
        }
        CACHE_MISSES.fetch_add(1, Ordering::Relaxed);
        let cost = SCRATCH.with(|s| dense_cost_into(inst, &mut s.borrow_mut()));
        cache_insert(key, CacheEntry { cost, schedule: None });
        cost
    }

    fn opt_schedule(&self, inst: &Instance) -> Schedule {
        let key = fingerprint(inst);
        if let Some(sched) =
            CACHE.with(|c| c.borrow().get(&key).and_then(|e| e.schedule.clone()))
        {
            CACHE_HITS.fetch_add(1, Ordering::Relaxed);
            return sched;
        }
        // A cost-only entry upgrades here (the wavefront re-runs with the
        // choice table — still counted as a miss).
        CACHE_MISSES.fetch_add(1, Ordering::Relaxed);
        let (cost, sched) = SCRATCH.with(|s| dense_solve_into(inst, &mut s.borrow_mut()));
        cache_insert(key, CacheEntry { cost, schedule: Some(sched.clone()) });
        sched
    }

    fn accelerates(&self, _inst: &Instance) -> bool {
        true // native path: every instance is served without fallback
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ReqFile;
    use crate::sched::{Scheduler, SimpleDp};
    use crate::sim::evaluate;
    use crate::testkit::{check_cases, InstanceGenConfig};

    #[test]
    fn matches_sparse_solver_on_random_instances() {
        let cfg = InstanceGenConfig { min_files: 1, max_files: 10, ..Default::default() };
        check_cases(0xDE15E, 60, &cfg, |inst| {
            let b = DenseBackend;
            let sparse = SimpleDp::cost(inst);
            assert_eq!(b.opt_cost(inst), sparse);
            assert_eq!(evaluate(inst, &b.opt_schedule(inst)).cost, sparse);
        });
    }

    #[test]
    fn schedule_achieves_reported_cost() {
        let inst = Instance::new(
            120,
            11,
            vec![
                ReqFile { l: 0, r: 4, x: 3 },
                ReqFile { l: 8, r: 20, x: 1 },
                ReqFile { l: 25, r: 26, x: 14 },
                ReqFile { l: 40, r: 70, x: 2 },
                ReqFile { l: 90, r: 95, x: 6 },
            ],
        )
        .unwrap();
        let b = DenseBackend;
        assert_eq!(evaluate(&inst, &b.opt_schedule(&inst)).cost, b.opt_cost(&inst));
        // The policy adapter must agree with the sparse scheduler's cost.
        let sparse = evaluate(&inst, &SimpleDp.schedule(&inst)).cost;
        assert_eq!(b.opt_cost(&inst), sparse);
    }

    #[test]
    fn result_cache_hits_on_repeated_batches() {
        // A distinctive instance (not reused by other tests on this
        // thread: each #[test] runs on its own thread, so the
        // thread-local cache starts empty; the global counters are shared
        // and only ever increase, so deltas are asserted with ≥).
        let inst = Instance::new(
            977,
            13,
            vec![
                ReqFile { l: 3, r: 41, x: 2 },
                ReqFile { l: 100, r: 177, x: 5 },
                ReqFile { l: 300, r: 301, x: 1 },
                ReqFile { l: 640, r: 900, x: 3 },
            ],
        )
        .unwrap();
        let b = DenseBackend;
        let (h0, m0) = dense_cache_stats();
        let c1 = b.opt_cost(&inst);
        let (_, m1) = dense_cache_stats();
        assert!(m1 > m0, "first evaluation must miss");
        let c2 = b.opt_cost(&inst);
        let (h2, _) = dense_cache_stats();
        assert!(h2 > h0, "identical batch must hit");
        assert_eq!(c1, c2);
        // A cost-only entry upgrades to a full entry on schedule demand…
        let s1 = b.opt_schedule(&inst);
        let (h3, m3) = dense_cache_stats();
        assert!(m3 > m1, "schedule after cost-only is a (counted) miss");
        // …after which the schedule is served from cache.
        let s2 = b.opt_schedule(&inst);
        let (h4, _) = dense_cache_stats();
        assert!(h4 > h3);
        assert_eq!(s1, s2);
        assert_eq!(evaluate(&inst, &s1).cost, c1, "cached results stay exact");
        assert_eq!(c1, SimpleDp::cost(&inst));
        // A different multiset must not hit the same entry.
        let other = Instance::new(
            977,
            13,
            vec![
                ReqFile { l: 3, r: 41, x: 3 }, // multiplicity differs
                ReqFile { l: 100, r: 177, x: 5 },
                ReqFile { l: 300, r: 301, x: 1 },
                ReqFile { l: 640, r: 900, x: 3 },
            ],
        )
        .unwrap();
        assert_eq!(b.opt_cost(&other), SimpleDp::cost(&other));
    }
}
