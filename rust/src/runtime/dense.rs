//! The always-available pure-Rust SimpleDP backend.
//!
//! Wraps the exact `i128` dense wavefront of
//! [`crate::sched::simpledp_dense`]: the same `(k × (n+1))` table the AOT
//! artifacts compute, evaluated bottom-up in Rust. Memory and time are
//! Θ(k·n) and Θ(k²·n) — identical asymptotics to the accelerated path,
//! with no artifact or feature requirements.
//!
//! Dispatch-path allocation discipline: each worker thread keeps one
//! [`DenseScratch`] (thread-local, the backend itself stays a stateless
//! `Copy` type shared through `Arc`), so coordinator batches and replay
//! dispatches on hot tapes reuse the Θ(k·n) buffers instead of allocating
//! them anew per call; cost-only queries additionally skip the choice
//! table entirely.

use std::cell::RefCell;

use crate::model::{Cost, Instance};
use crate::sched::simpledp_dense::{dense_cost_into, dense_solve_into, DenseScratch};
use crate::sched::Schedule;

use super::SimpleDpBackend;

thread_local! {
    static SCRATCH: RefCell<DenseScratch> = RefCell::new(DenseScratch::default());
}

/// Pure-Rust dense SimpleDP backend (the default).
#[derive(Debug, Clone, Copy, Default)]
pub struct DenseBackend;

impl SimpleDpBackend for DenseBackend {
    fn id(&self) -> &'static str {
        "dense"
    }

    fn opt_cost(&self, inst: &Instance) -> Cost {
        SCRATCH.with(|s| dense_cost_into(inst, &mut s.borrow_mut()))
    }

    fn opt_schedule(&self, inst: &Instance) -> Schedule {
        SCRATCH.with(|s| dense_solve_into(inst, &mut s.borrow_mut()).1)
    }

    fn accelerates(&self, _inst: &Instance) -> bool {
        true // native path: every instance is served without fallback
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ReqFile;
    use crate::sched::{Scheduler, SimpleDp};
    use crate::sim::evaluate;
    use crate::testkit::{check_cases, InstanceGenConfig};

    #[test]
    fn matches_sparse_solver_on_random_instances() {
        let cfg = InstanceGenConfig { min_files: 1, max_files: 10, ..Default::default() };
        check_cases(0xDE15E, 60, &cfg, |inst| {
            let b = DenseBackend;
            let sparse = SimpleDp::cost(inst);
            assert_eq!(b.opt_cost(inst), sparse);
            assert_eq!(evaluate(inst, &b.opt_schedule(inst)).cost, sparse);
        });
    }

    #[test]
    fn schedule_achieves_reported_cost() {
        let inst = Instance::new(
            120,
            11,
            vec![
                ReqFile { l: 0, r: 4, x: 3 },
                ReqFile { l: 8, r: 20, x: 1 },
                ReqFile { l: 25, r: 26, x: 14 },
                ReqFile { l: 40, r: 70, x: 2 },
                ReqFile { l: 90, r: 95, x: 6 },
            ],
        )
        .unwrap();
        let b = DenseBackend;
        assert_eq!(evaluate(&inst, &b.opt_schedule(&inst)).cost, b.opt_cost(&inst));
        // The policy adapter must agree with the sparse scheduler's cost.
        let sparse = evaluate(&inst, &SimpleDp.schedule(&inst)).cost;
        assert_eq!(b.opt_cost(&inst), sparse);
    }
}
