//! PJRT client wrapper: compile-once / execute-many over HLO-text
//! artifacts. Compiled only with `--features xla`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Errors from the XLA runtime layer.
///
/// `Display`/`Error` are hand-implemented (no `thiserror`) so the `xla`
/// feature builds with no registry access at all.
#[derive(Debug)]
pub enum RuntimeError {
    /// `name.hlo.txt` is missing from the artifact directory.
    MissingArtifact(PathBuf),
    /// An error surfaced by the underlying XLA bindings.
    Xla(xla::Error),
    /// The artifact executed but returned an unexpected output shape.
    BadArity { name: String, got: usize, expected: usize },
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::MissingArtifact(path) => {
                write!(f, "artifact not found: {} (run `make artifacts`)", path.display())
            }
            RuntimeError::Xla(e) => write!(f, "xla error: {e}"),
            RuntimeError::BadArity { name, got, expected } => {
                write!(f, "artifact {name} returned {got} outputs, expected {expected}")
            }
        }
    }
}

impl std::error::Error for RuntimeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RuntimeError::Xla(e) => Some(e),
            _ => None,
        }
    }
}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> RuntimeError {
        RuntimeError::Xla(e)
    }
}

/// A PJRT CPU client plus a cache of compiled executables keyed by
/// artifact name. Thread-safe: executions are internally serialized by the
/// mutex only during cache lookup; PJRT executions themselves run without
/// holding it.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Create an engine loading artifacts from `dir`.
    pub fn new(dir: impl AsRef<Path>) -> Result<Engine, RuntimeError> {
        Ok(Engine {
            client: xla::PjRtClient::cpu()?,
            dir: dir.as_ref().to_path_buf(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Artifact directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// True if `name.hlo.txt` exists in the artifact directory.
    pub fn has_artifact(&self, name: &str) -> bool {
        self.dir.join(format!("{name}.hlo.txt")).exists()
    }

    /// Compile (or fetch from cache) the artifact `name`.
    pub fn load(
        &self,
        name: &str,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>, RuntimeError> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(std::sync::Arc::clone(exe));
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            return Err(RuntimeError::MissingArtifact(path));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().expect("artifact path is valid UTF-8"),
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), std::sync::Arc::clone(&exe));
        Ok(exe)
    }

    /// Execute artifact `name` on f64 input tensors, returning the first
    /// (tuple-unwrapped) output as a flat f64 vector.
    ///
    /// `inputs` are `(data, shape)` pairs; jax artifacts are lowered with
    /// `return_tuple=True`, so the single output is a 1-tuple.
    pub fn run_f64(
        &self,
        name: &str,
        inputs: &[(&[f64], &[i64])],
    ) -> Result<Vec<f64>, RuntimeError> {
        let exe = self.load(name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, shape)| {
                let lit = xla::Literal::vec1(data);
                if shape.len() == 1 && shape[0] as usize == data.len() {
                    Ok(lit)
                } else {
                    lit.reshape(shape)
                }
            })
            .collect::<Result<_, xla::Error>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?;
        let first = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| RuntimeError::BadArity {
                name: name.to_string(),
                got: 0,
                expected: 1,
            })?
            .to_literal_sync()?;
        let out = first.to_tuple1()?;
        Ok(out.to_vec::<f64>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// HLO text for a trivial computation `f(x) = (x * 2 + 1,)` over
    /// f64[4], hand-written so the engine tests do not depend on `make
    /// artifacts` having run.
    const DOUBLER_HLO: &str = r#"HloModule doubler, entry_computation_layout={(f64[4]{0})->(f64[4]{0})}

ENTRY main {
  x = f64[4]{0} parameter(0)
  two = f64[] constant(2)
  btwo = f64[4]{0} broadcast(two), dimensions={}
  one = f64[] constant(1)
  bone = f64[4]{0} broadcast(one), dimensions={}
  mul = f64[4]{0} multiply(x, btwo)
  add = f64[4]{0} add(mul, bone)
  ROOT t = (f64[4]{0}) tuple(add)
}
"#;

    fn engine_with_doubler(tag: &str) -> (Engine, std::path::PathBuf) {
        let dir = std::env::temp_dir()
            .join(format!("tapesched_rt_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("doubler.hlo.txt"), DOUBLER_HLO).unwrap();
        (Engine::new(&dir).expect("PJRT CPU client"), dir)
    }

    /// The vendored `xla` stub cannot compile or execute; real bindings
    /// can. Tests that need execution skip on the stub's error.
    fn skip_if_stub<T>(r: Result<T, RuntimeError>, what: &str) -> Option<T> {
        match r {
            Ok(v) => Some(v),
            Err(RuntimeError::Xla(e)) => {
                eprintln!("skipping {what}: xla bindings cannot execute ({e})");
                None
            }
            Err(other) => panic!("{what}: unexpected error {other:?}"),
        }
    }

    #[test]
    fn compiles_and_runs_hlo_text() {
        let (eng, dir) = engine_with_doubler("run");
        assert!(eng.has_artifact("doubler"));
        let run = eng.run_f64("doubler", &[(&[1.0, 2.0, 3.0, 4.0], &[4])]);
        if let Some(out) = skip_if_stub(run, "compiles_and_runs_hlo_text") {
            assert_eq!(out, vec![3.0, 5.0, 7.0, 9.0]);
        }
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn caches_compiled_executables() {
        let (eng, dir) = engine_with_doubler("cache");
        if let Some(a) = skip_if_stub(eng.load("doubler"), "caches_compiled_executables") {
            let b = eng.load("doubler").unwrap();
            assert!(std::sync::Arc::ptr_eq(&a, &b));
        }
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let (eng, dir) = engine_with_doubler("missing");
        match eng.run_f64("nope", &[]) {
            Err(RuntimeError::MissingArtifact(p)) => {
                assert!(p.ends_with("nope.hlo.txt"));
                let msg = RuntimeError::MissingArtifact(p).to_string();
                assert!(msg.contains("make artifacts"));
            }
            other => panic!("expected MissingArtifact, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(dir);
    }
}
