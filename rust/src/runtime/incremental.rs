//! Incremental dense SimpleDP re-solve for growing batches.
//!
//! A `Batcher` open batch grows one request at a time, and every growth
//! step used to pay a full Θ(k²·n) dense wavefront. But when the batch
//! grows by a *new last file* (an append in sorted tape order), almost the
//! whole previous table is still exact: appending file `k` changes no
//! `ℓ/r/x/n_ℓ` value of files `0..k`, so row `b < k` cells differ from the
//! old table only where the old evaluation was touched by the edge clamp —
//! the skip branch reads row `b−1` at column `min(ns + x_b, ns_max)` and
//! `ns_max` just grew. [`IncrementalTable`] keeps the full value table as
//! per-file rows and repairs exactly that suffix region:
//!
//! - row 0 gains the new columns (`T[0, ns] = 2·s(0)·ns`, never stale);
//! - row `b ≥ 1` is recomputed for columns `ns ≥ τ_b` with
//!   `τ_b = τ_{b−1} − x_b` (saturating), `τ_0 = n_old + 1`: a
//!   conservative stale front covering (a) the direct clamp
//!   (`ns + x_b > n_old`), (b) stale skip reads (the skip branch reads
//!   column `ns + x_b ≥ τ_{b−1}`, already repaired when row `b` runs), and
//!   (c) stale detour reads (a detour reads row `c−1` at the *same*
//!   column, and `τ` is nonincreasing in `b`, so column `ns < τ_b ≤ τ_{c−1}`
//!   is never stale);
//! - the appended file's own row is computed in full.
//!
//! Every repaired cell therefore reads only never-stale or
//! already-repaired cells, which makes the incremental cost **bit-equal**
//! to a from-scratch [`dense_cost`] — property-tested against the sparse
//! solver and ci-gated. For a batch grown by unit-multiplicity appends the
//! repair work is Θ(b·(b + x_k)) per step (~k³ total) instead of Θ(k²·n)
//! per step (~k³·n̄ total): the win is the per-step factor n.
//!
//! Any non-append mutation — a multiplicity bump, an insertion before the
//! last file, a different tape geometry or `U` — falls back to a full
//! rebuild (same table layout, so the next append extends again).
//! Schedules always go through the scratch solver: reconstruction needs
//! the choice table, which the repair path deliberately does not maintain.
//!
//! [`IncrementalBackend`] wraps a thread-local table behind the
//! [`SimpleDpBackend`] seam (CLI id `incremental`), with process-wide
//! append/fallback counters exported via [`incremental_stats`].
//!
//! [`dense_cost`]: crate::sched::simpledp_dense::dense_cost

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::model::{virtual_lb, Cost, Instance, ReqFile};
use crate::sched::simpledp_dense::{dense_solve_into, DenseScratch};
use crate::sched::Schedule;

use super::SimpleDpBackend;

static INC_APPENDS: AtomicU64 = AtomicU64::new(0);
static INC_FALLBACKS: AtomicU64 = AtomicU64::new(0);

/// Process-wide incremental-solver counters: `(appends, fallbacks)`,
/// summed over every thread since process start. An append means a batch
/// growth step skipped the from-scratch wavefront and repaired the stale
/// suffix instead; a fallback is a full rebuild.
pub fn incremental_stats() -> (u64, u64) {
    (INC_APPENDS.load(Ordering::Relaxed), INC_FALLBACKS.load(Ordering::Relaxed))
}

/// The dense SimpleDP value table of the last solved instance, stored as
/// one row per requested file so an append extends in place.
#[derive(Debug, Default)]
pub struct IncrementalTable {
    tape_len: u64,
    u: u64,
    files: Vec<ReqFile>,
    /// `rows[b][ns]` = `T[b, ns]`, each row of length `width`.
    rows: Vec<Vec<Cost>>,
    /// `n + 1` for the stored instance.
    width: usize,
}

impl IncrementalTable {
    pub fn new() -> IncrementalTable {
        IncrementalTable::default()
    }

    /// Whether `inst` extends the stored instance by exactly one appended
    /// last file (same tape, same `U`, identical prefix).
    fn is_append(&self, inst: &Instance) -> bool {
        !self.files.is_empty()
            && self.tape_len == inst.tape_len()
            && self.u == inst.u()
            && inst.k() == self.files.len() + 1
            && inst.files()[..self.files.len()] == self.files[..]
    }

    /// Whether `inst` is byte-identical to the stored instance.
    fn is_same(&self, inst: &Instance) -> bool {
        self.tape_len == inst.tape_len()
            && self.u == inst.u()
            && inst.files() == &self.files[..]
    }

    /// One cell of the dense recurrence, reading rows `0..b` of `rows`
    /// (must already be correct at the columns the cell reads — see the
    /// module docs for the repair invariant).
    fn cell(inst: &Instance, below: &[Vec<Cost>], b: usize, ns: usize, ns_max: usize) -> Cost {
        let xb = inst.x(b) as usize;
        let shifted = (ns + xb).min(ns_max);
        let gap2 = 2 * (inst.r(b) - inst.r(b - 1)) as Cost;
        let lead2 = 2 * (inst.l(b) - inst.r(b - 1)) as Cost * inst.x(b) as Cost;
        let mut best = below[b - 1][shifted] + gap2 * ns as Cost + lead2;
        let u = inst.u() as Cost;
        for c in 1..=b {
            let span2 = 2 * (inst.r(b) - inst.r(c - 1)) as Cost;
            let det2 = 2 * (u + inst.r(b) as Cost - inst.l(c) as Cost);
            let v = below[c - 1][ns]
                + span2 * ns as Cost
                + det2 * (ns as Cost + inst.nl(c) as Cost)
                + 2 * inst.in_detour_span_cost(c, b);
            if v < best {
                best = v;
            }
        }
        best
    }

    /// Full rebuild: the same bottom-up wavefront as
    /// [`crate::sched::simpledp_dense::dense_table`], laid out per row.
    fn rebuild(&mut self, inst: &Instance) {
        let k = inst.k();
        let ns_max = inst.n() as usize;
        let width = ns_max + 1;
        self.rows.resize_with(k, Vec::new);
        self.rows.truncate(k);
        for (b, row) in self.rows.iter_mut().enumerate() {
            row.clear();
            row.resize(width, 0);
            if b == 0 {
                for (ns, v) in row.iter_mut().enumerate() {
                    *v = 2 * inst.s(0) as Cost * ns as Cost;
                }
            }
        }
        for b in 1..k {
            let (below, rest) = self.rows.split_at_mut(b);
            let row = &mut rest[0];
            for (ns, v) in row.iter_mut().enumerate() {
                *v = Self::cell(inst, below, b, ns, ns_max);
            }
        }
        self.tape_len = inst.tape_len();
        self.u = inst.u();
        self.files = inst.files().to_vec();
        self.width = width;
    }

    /// Append repair: extend row 0, repair each existing row's stale
    /// suffix (`ns ≥ τ_b`, `τ_b = τ_{b−1} − x_b` saturating from
    /// `τ_0 = n_old + 1`), then compute the new last row in full.
    fn extend(&mut self, inst: &Instance) {
        let k = inst.k();
        let ns_max = inst.n() as usize;
        let width = ns_max + 1;
        debug_assert_eq!(k, self.rows.len() + 1);
        self.rows[0].resize(width, 0);
        for ns in self.width..width {
            self.rows[0][ns] = 2 * inst.s(0) as Cost * ns as Cost;
        }
        let mut tau = self.width; // τ_0 = n_old + 1
        for b in 1..k - 1 {
            tau = tau.saturating_sub(inst.x(b) as usize);
            let (below, rest) = self.rows.split_at_mut(b);
            let row = &mut rest[0];
            row.resize(width, 0);
            for ns in tau..width {
                row[ns] = Self::cell(inst, below, b, ns, ns_max);
            }
        }
        let b = k - 1;
        let mut row = vec![0; width];
        for (ns, v) in row.iter_mut().enumerate() {
            *v = Self::cell(inst, &self.rows, b, ns, ns_max);
        }
        self.rows.push(row);
        self.files.push(inst.files()[b]);
        self.width = width;
    }

    /// Exact optimal disjoint-detour cost (including `VirtualLB`) of
    /// `inst`, reusing the stored table when `inst` is the stored
    /// instance or a one-file append of it, rebuilding otherwise. The
    /// second element reports which path ran (`true` = incremental).
    pub fn opt_cost(&mut self, inst: &Instance) -> (Cost, bool) {
        let incremental = if !self.rows.is_empty() && self.is_same(inst) {
            true
        } else if self.is_append(inst) {
            self.extend(inst);
            true
        } else {
            self.rebuild(inst);
            false
        };
        let cost = self.rows[inst.k() - 1][0] + virtual_lb(inst);
        (cost, incremental)
    }
}

thread_local! {
    static TABLE: RefCell<IncrementalTable> = RefCell::new(IncrementalTable::new());
    static SCRATCH: RefCell<DenseScratch> = RefCell::new(DenseScratch::default());
}

/// Incremental dense SimpleDP backend: cost queries over a growing batch
/// repair the previous thread-local table instead of re-solving from
/// scratch; everything else (non-append mutations, schedule requests)
/// serves through the exact scratch solver.
#[derive(Debug, Clone, Copy, Default)]
pub struct IncrementalBackend;

impl SimpleDpBackend for IncrementalBackend {
    fn id(&self) -> &'static str {
        "incremental"
    }

    fn opt_cost(&self, inst: &Instance) -> Cost {
        let (cost, incremental) = TABLE.with(|t| t.borrow_mut().opt_cost(inst));
        if incremental {
            INC_APPENDS.fetch_add(1, Ordering::Relaxed);
        } else {
            INC_FALLBACKS.fetch_add(1, Ordering::Relaxed);
        }
        cost
    }

    fn opt_schedule(&self, inst: &Instance) -> Schedule {
        // Reconstruction needs the choice table the repair path does not
        // maintain: full solve through the reusable scratch buffers.
        SCRATCH.with(|s| dense_solve_into(inst, &mut s.borrow_mut())).1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{Scheduler, SimpleDp};
    use crate::sim::evaluate;
    use crate::util::rng::Rng;

    fn grow_step(rng: &mut Rng, files: &mut Vec<ReqFile>) -> bool {
        // 1-in-4 steps mutate an existing file's multiplicity (a
        // non-append growth: the same batch gaining a duplicate request),
        // the rest append a fresh file after the current last one.
        if !files.is_empty() && rng.below(4) == 0 {
            let i = rng.below(files.len() as u64) as usize;
            files[i].x += 1;
            false
        } else {
            let prev_r = files.last().map(|f| f.r).unwrap_or(0);
            let l = prev_r + 1 + rng.below(5);
            let r = l + 1 + rng.below(8);
            files.push(ReqFile { l, r, x: 1 + rng.below(3) });
            true
        }
    }

    #[test]
    fn incremental_cost_is_bit_equal_on_random_grow_sequences() {
        // The property the ci gate leans on: along random grow sequences
        // (appends interleaved with multiplicity bumps), the incremental
        // cost equals the scratch solver's bit for bit, and BOTH paths
        // (append repair and full fallback) are exercised.
        let mut rng = Rng::new(0x1C41);
        let (mut appends, mut fallbacks) = (0u64, 0u64);
        for case in 0..25 {
            let mut table = IncrementalTable::new();
            let u = rng.below(9);
            let mut files: Vec<ReqFile> = Vec::new();
            for step in 0..18 {
                let appended = grow_step(&mut rng, &mut files);
                let inst = Instance::new(600, u, files.clone()).unwrap();
                let (cost, incremental) = table.opt_cost(&inst);
                assert_eq!(
                    cost,
                    SimpleDp::cost(&inst),
                    "case {case} step {step} (append: {appended})"
                );
                // The first step has no table to extend; later appends
                // must take the incremental path, mutations must not.
                if step > 0 {
                    assert_eq!(incremental, appended, "case {case} step {step}");
                }
                if incremental { appends += 1 } else { fallbacks += 1 };
            }
        }
        assert!(appends > 100, "append repair under-exercised: {appends}");
        assert!(fallbacks > 25, "fallback path under-exercised: {fallbacks}");
    }

    #[test]
    fn incremental_handles_clamp_heavy_multiplicities() {
        // Large multiplicities drive the skip-branch clamp hard (the
        // stale region the repair exists for): dominant x on the first,
        // middle, and appended file.
        let mut table = IncrementalTable::new();
        let seqs: Vec<Vec<ReqFile>> = vec![
            vec![
                ReqFile { l: 0, r: 5, x: 60 },
                ReqFile { l: 20, r: 30, x: 1 },
                ReqFile { l: 40, r: 45, x: 1 },
                ReqFile { l: 50, r: 52, x: 7 },
            ],
            vec![
                ReqFile { l: 3, r: 6, x: 1 },
                ReqFile { l: 20, r: 30, x: 60 },
                ReqFile { l: 40, r: 45, x: 1 },
                ReqFile { l: 90, r: 99, x: 2 },
            ],
            vec![
                ReqFile { l: 5, r: 6, x: 2 },
                ReqFile { l: 6, r: 30, x: 1 },
                ReqFile { l: 31, r: 32, x: 8 },
                ReqFile { l: 60, r: 61, x: 55 },
            ],
        ];
        for (i, seq) in seqs.iter().enumerate() {
            for step in 1..=seq.len() {
                let inst = Instance::new(200, 3, seq[..step].to_vec()).unwrap();
                let (cost, incremental) = table.opt_cost(&inst);
                assert_eq!(cost, SimpleDp::cost(&inst), "seq {i} step {step}");
                // Each sequence restarts (different first file): step 1
                // falls back, every later step is a pure append.
                assert_eq!(incremental, step > 1, "seq {i} step {step}");
            }
        }
    }

    #[test]
    fn incremental_repeated_instance_is_served_from_the_table() {
        let files = vec![
            ReqFile { l: 5, r: 6, x: 2 },
            ReqFile { l: 6, r: 30, x: 1 },
            ReqFile { l: 31, r: 32, x: 8 },
        ];
        let inst = Instance::new(100, 3, files).unwrap();
        let mut table = IncrementalTable::new();
        let (c1, first) = table.opt_cost(&inst);
        let (c2, second) = table.opt_cost(&inst);
        assert!(!first, "first solve must rebuild");
        assert!(second, "identical re-solve must reuse the table");
        assert_eq!(c1, c2);
        assert_eq!(c1, SimpleDp::cost(&inst));
        // A different U on the same files must NOT reuse the table.
        let (c3, third) = table.opt_cost(&inst.with_u(9));
        assert!(!third);
        assert_eq!(c3, SimpleDp::cost(&inst.with_u(9)));
    }

    #[test]
    fn incremental_backend_serves_exact_costs_and_schedules() {
        let b = IncrementalBackend;
        assert_eq!(b.id(), "incremental");
        let (a0, f0) = incremental_stats();
        let mut files = vec![ReqFile { l: 2, r: 4, x: 2 }];
        let mut last = None;
        for add in [(10u64, 30u64, 5u64), (33, 34, 1), (50, 80, 4), (90, 99, 2)] {
            files.push(ReqFile { l: add.0, r: add.1, x: add.2 });
            let inst = Instance::new(110, 0, files.clone()).unwrap();
            let expected = SimpleDp::cost(&inst);
            assert_eq!(b.opt_cost(&inst), expected);
            let sched = b.opt_schedule(&inst);
            assert_eq!(evaluate(&inst, &sched).cost, expected);
            last = Some(inst);
        }
        let (a1, f1) = incremental_stats();
        assert!(a1 > a0, "appends must be counted");
        assert!(f1 > f0, "the first solve counts as a fallback");
        // The schedule detour list matches the sparse solver's cost too.
        let inst = last.unwrap();
        let sparse = evaluate(&inst, &SimpleDp.schedule(&inst)).cost;
        assert_eq!(b.opt_cost(&inst), sparse);
    }
}
