//! Incremental dense SimpleDP re-solve for growing batches.
//!
//! A `Batcher` open batch grows one request at a time, and every growth
//! step used to pay a full Θ(k²·n) dense wavefront. But when the batch
//! grows by a *new last file* (an append in sorted tape order), almost the
//! whole previous table is still exact: appending file `k` changes no
//! `ℓ/r/x/n_ℓ` value of files `0..k`, so row `b < k` cells differ from the
//! old table only where the old evaluation was touched by the edge clamp —
//! the skip branch reads row `b−1` at column `min(ns + x_b, ns_max)` and
//! `ns_max` just grew. [`IncrementalTable`] keeps the full value table as
//! per-file rows and repairs exactly that suffix region:
//!
//! - row 0 gains the new columns (`T[0, ns] = 2·s(0)·ns`, never stale);
//! - row `b ≥ 1` is recomputed for columns `ns ≥ τ_b` with
//!   `τ_b = τ_{b−1} − x_b` (saturating), `τ_0 = n_old + 1`: a
//!   conservative stale front covering (a) the direct clamp
//!   (`ns + x_b > n_old`), (b) stale skip reads (the skip branch reads
//!   column `ns + x_b ≥ τ_{b−1}`, already repaired when row `b` runs), and
//!   (c) stale detour reads (a detour reads row `c−1` at the *same*
//!   column, and `τ` is nonincreasing in `b`, so column `ns < τ_b ≤ τ_{c−1}`
//!   is never stale);
//! - the appended file's own row is computed in full.
//!
//! Every repaired cell therefore reads only never-stale or
//! already-repaired cells, which makes the incremental cost **bit-equal**
//! to a from-scratch [`dense_cost`] — property-tested against the sparse
//! solver and ci-gated. For a batch grown by unit-multiplicity appends the
//! repair work is Θ(b·(b + x_k)) per step (~k³ total) instead of Θ(k²·n)
//! per step (~k³·n̄ total): the win is the per-step factor n.
//!
//! Any non-append mutation — a multiplicity bump, an insertion before the
//! last file, a different tape geometry or `U` — falls back to a full
//! rebuild (same table layout, so the next append extends again).
//!
//! ## Schedules without a choice table
//!
//! The repair path deliberately keeps no per-cell decision record, but the
//! schedule is still reconstructible *exactly* from values alone
//! (the value walk inside [`IncrementalTable::opt_solve`]): at each cell
//! re-evaluates the skip branch first and takes it on equality (ties favor
//! skip, exactly like `fill_dense`'s strict-`<` detour updates), otherwise
//! scans `c = 1..=b` ascending for the first branch reproducing the cell
//! value (the recorded choice in a tracked solve is the first `c`
//! attaining the final minimum). Arithmetic is exact `i128`, so the
//! decisions — and therefore the detour list — are bit-identical to
//! [`dense_solve_into`]'s, which is what lets the serving path assert
//! per-request service times unchanged under `--backend incremental`.
//!
//! ## The serving path
//!
//! [`IncrementalBackend`] keys thread-local tables by *instance prefix
//! fingerprint* (tape geometry + `U` + first requested file), one table
//! per hot tape prefix per thread — coordinator drive workers each get
//! their own family for free. [`IncrementalTable::opt_solve`] brings the
//! keyed table to the queried instance by the cheapest route: nothing when
//! the instance is stored verbatim, a chain of one-file append repairs
//! when it extends the stored batch (the growing-backlog case), or a
//! restart from the first file followed by append repairs otherwise.
//! Process-wide append/rebuild counters are exported via
//! [`incremental_stats`]; per-thread deltas for the coordinator's
//! `MetricsSnapshot` via [`take_thread_incremental_stats`].
//!
//! [`dense_cost`]: crate::sched::simpledp_dense::dense_cost

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::model::{virtual_lb, Cost, Instance, ReqFile};
use crate::sched::simpledp_dense::{dense_solve_into, DenseScratch};
use crate::sched::{Detour, Schedule};

use super::SimpleDpBackend;

static INC_APPENDS: AtomicU64 = AtomicU64::new(0);
static INC_FALLBACKS: AtomicU64 = AtomicU64::new(0);

/// Process-wide incremental-solver counters: `(appends, fallbacks)`,
/// summed over every thread since process start. An append means a batch
/// growth step skipped the from-scratch wavefront and repaired the stale
/// suffix instead; a fallback is a full rebuild.
pub fn incremental_stats() -> (u64, u64) {
    (INC_APPENDS.load(Ordering::Relaxed), INC_FALLBACKS.load(Ordering::Relaxed))
}

thread_local! {
    /// This thread's not-yet-collected (appends, rebuilds) deltas — the
    /// per-worker attribution behind the coordinator's
    /// `incremental_appends`/`incremental_rebuilds` snapshot counters
    /// (the global atomics above cannot distinguish threads).
    static THREAD_DELTAS: Cell<(u64, u64)> = const { Cell::new((0, 0)) };
}

/// Drain the calling thread's incremental-solver `(appends, rebuilds)`
/// deltas accumulated since the previous call. A coordinator drive worker
/// calls this after each dispatch to attribute the solver's work to its
/// own [`crate::coordinator::SharedMetrics`]; threads that never run the
/// incremental backend always read `(0, 0)`.
pub fn take_thread_incremental_stats() -> (u64, u64) {
    THREAD_DELTAS.with(|d| d.replace((0, 0)))
}

fn count_incremental(appends: u64, rebuilds: u64) {
    if appends > 0 {
        INC_APPENDS.fetch_add(appends, Ordering::Relaxed);
    }
    if rebuilds > 0 {
        INC_FALLBACKS.fetch_add(rebuilds, Ordering::Relaxed);
    }
    if appends > 0 || rebuilds > 0 {
        THREAD_DELTAS.with(|d| {
            let (a, r) = d.get();
            d.set((a + appends, r + rebuilds));
        });
    }
}

/// The dense SimpleDP value table of the last solved instance, stored as
/// one row per requested file so an append extends in place.
#[derive(Debug, Default)]
pub struct IncrementalTable {
    tape_len: u64,
    u: u64,
    files: Vec<ReqFile>,
    /// `rows[b][ns]` = `T[b, ns]`, each row of length `width`.
    rows: Vec<Vec<Cost>>,
    /// `n + 1` for the stored instance.
    width: usize,
}

impl IncrementalTable {
    pub fn new() -> IncrementalTable {
        IncrementalTable::default()
    }

    /// Whether `inst` extends the stored instance by exactly one appended
    /// last file (same tape, same `U`, identical prefix).
    fn is_append(&self, inst: &Instance) -> bool {
        !self.files.is_empty()
            && self.tape_len == inst.tape_len()
            && self.u == inst.u()
            && inst.k() == self.files.len() + 1
            && inst.files()[..self.files.len()] == self.files[..]
    }

    /// Whether `inst` is byte-identical to the stored instance.
    fn is_same(&self, inst: &Instance) -> bool {
        self.tape_len == inst.tape_len()
            && self.u == inst.u()
            && inst.files() == &self.files[..]
    }

    /// One cell of the dense recurrence, reading rows `0..b` of `rows`
    /// (must already be correct at the columns the cell reads — see the
    /// module docs for the repair invariant).
    fn cell(inst: &Instance, below: &[Vec<Cost>], b: usize, ns: usize, ns_max: usize) -> Cost {
        let xb = inst.x(b) as usize;
        let shifted = (ns + xb).min(ns_max);
        let gap2 = 2 * (inst.r(b) - inst.r(b - 1)) as Cost;
        let lead2 = 2 * (inst.l(b) - inst.r(b - 1)) as Cost * inst.x(b) as Cost;
        let mut best = below[b - 1][shifted] + gap2 * ns as Cost + lead2;
        let u = inst.u() as Cost;
        for c in 1..=b {
            let span2 = 2 * (inst.r(b) - inst.r(c - 1)) as Cost;
            let det2 = 2 * (u + inst.r(b) as Cost - inst.l(c) as Cost);
            let v = below[c - 1][ns]
                + span2 * ns as Cost
                + det2 * (ns as Cost + inst.nl(c) as Cost)
                + 2 * inst.in_detour_span_cost(c, b);
            if v < best {
                best = v;
            }
        }
        best
    }

    /// Full rebuild: the same bottom-up wavefront as
    /// [`crate::sched::simpledp_dense::dense_table`], laid out per row.
    fn rebuild(&mut self, inst: &Instance) {
        let k = inst.k();
        let ns_max = inst.n() as usize;
        let width = ns_max + 1;
        self.rows.resize_with(k, Vec::new);
        self.rows.truncate(k);
        for (b, row) in self.rows.iter_mut().enumerate() {
            row.clear();
            row.resize(width, 0);
            if b == 0 {
                for (ns, v) in row.iter_mut().enumerate() {
                    *v = 2 * inst.s(0) as Cost * ns as Cost;
                }
            }
        }
        for b in 1..k {
            let (below, rest) = self.rows.split_at_mut(b);
            let row = &mut rest[0];
            for (ns, v) in row.iter_mut().enumerate() {
                *v = Self::cell(inst, below, b, ns, ns_max);
            }
        }
        self.tape_len = inst.tape_len();
        self.u = inst.u();
        self.files = inst.files().to_vec();
        self.width = width;
    }

    /// Append repair: extend row 0, repair each existing row's stale
    /// suffix (`ns ≥ τ_b`, `τ_b = τ_{b−1} − x_b` saturating from
    /// `τ_0 = n_old + 1`), then compute the new last row in full.
    fn extend(&mut self, inst: &Instance) {
        let k = inst.k();
        let ns_max = inst.n() as usize;
        let width = ns_max + 1;
        debug_assert_eq!(k, self.rows.len() + 1);
        self.rows[0].resize(width, 0);
        for ns in self.width..width {
            self.rows[0][ns] = 2 * inst.s(0) as Cost * ns as Cost;
        }
        let mut tau = self.width; // τ_0 = n_old + 1
        for b in 1..k - 1 {
            tau = tau.saturating_sub(inst.x(b) as usize);
            let (below, rest) = self.rows.split_at_mut(b);
            let row = &mut rest[0];
            row.resize(width, 0);
            for ns in tau..width {
                row[ns] = Self::cell(inst, below, b, ns, ns_max);
            }
        }
        let b = k - 1;
        let mut row = vec![0; width];
        for (ns, v) in row.iter_mut().enumerate() {
            *v = Self::cell(inst, &self.rows, b, ns, ns_max);
        }
        self.rows.push(row);
        self.files.push(inst.files()[b]);
        self.width = width;
    }

    /// Exact optimal disjoint-detour cost (including `VirtualLB`) of
    /// `inst`, reusing the stored table when `inst` is the stored
    /// instance or a one-file append of it, rebuilding otherwise. The
    /// second element reports which path ran (`true` = incremental).
    pub fn opt_cost(&mut self, inst: &Instance) -> (Cost, bool) {
        let incremental = if !self.rows.is_empty() && self.is_same(inst) {
            true
        } else if self.is_append(inst) {
            self.extend(inst);
            true
        } else {
            self.rebuild(inst);
            false
        };
        let cost = self.rows[inst.k() - 1][0] + virtual_lb(inst);
        (cost, incremental)
    }

    /// Length of the stored file vector when `inst` extends it (same tape
    /// geometry and `U`, stored files an exact prefix of `inst`'s): the
    /// rows that can be kept. `0` means no reuse.
    fn reusable_prefix(&self, inst: &Instance) -> usize {
        let len = self.files.len();
        if len > 0
            && self.tape_len == inst.tape_len()
            && self.u == inst.u()
            && len <= inst.k()
            && inst.files()[..len] == self.files[..]
        {
            len
        } else {
            0
        }
    }

    /// The `j`-file prefix of `inst` as its own instance (the shape each
    /// append-repair step solves).
    fn prefix_instance(inst: &Instance, j: usize) -> Instance {
        Instance::new(inst.tape_len(), inst.u(), inst.files()[..j].to_vec())
            .expect("a prefix of a valid instance is itself valid")
    }

    /// Bring the table to `inst` by the cheapest exact route: no work when
    /// `inst` is stored verbatim, one append repair per missing file when
    /// it extends the stored batch, or a restart from the one-file prefix
    /// (plus append repairs) for any other shape. Returns the
    /// `(appends, rebuilds)` performed.
    ///
    /// Building an unrelated instance through the append chain instead of
    /// one full wavefront is itself cheaper (each step's new row is
    /// `Θ(j·n_j)` against the prefix's `n_j`, not the final `n`) and keeps
    /// the stored batch a growth frontier: the next instance extending it
    /// pays only its own appended columns.
    fn sync(&mut self, inst: &Instance) -> (u64, u64) {
        let k = inst.k();
        let mut stored = self.reusable_prefix(inst);
        let mut rebuilds = 0;
        if stored == 0 {
            self.rebuild(&Self::prefix_instance(inst, 1));
            rebuilds = 1;
            stored = 1;
        }
        let appends = (k - stored) as u64;
        for j in stored + 1..=k {
            if j == k {
                self.extend(inst);
            } else {
                self.extend(&Self::prefix_instance(inst, j));
            }
        }
        (appends, rebuilds)
    }

    /// Reconstruct the optimal schedule from table values alone, walking
    /// root-down and re-deriving each cell's decision by *exact* equality:
    /// the skip branch is tested first (ties favor skip, as in
    /// `fill_dense`, whose detour updates are strict `<`), then detour
    /// branches `c = 1..=b` ascending — the first branch reproducing the
    /// cell value is the one a tracked solve would have recorded. The
    /// returned detour list is therefore bit-identical to
    /// [`dense_solve_into`]'s.
    ///
    /// The table must already be synced to `inst`.
    fn reconstruct(&self, inst: &Instance) -> Schedule {
        let ns_max = inst.n() as usize;
        let u = inst.u() as Cost;
        let mut detours = Vec::new();
        let (mut b, mut ns) = (inst.k() - 1, 0usize);
        while b > 0 {
            let here = self.rows[b][ns];
            let xb = inst.x(b) as usize;
            let shifted = (ns + xb).min(ns_max);
            let gap2 = 2 * (inst.r(b) - inst.r(b - 1)) as Cost;
            let lead2 = 2 * (inst.l(b) - inst.r(b - 1)) as Cost * inst.x(b) as Cost;
            if self.rows[b - 1][shifted] + gap2 * ns as Cost + lead2 == here {
                ns = shifted;
                b -= 1;
                continue;
            }
            let mut chosen = None;
            for c in 1..=b {
                let span2 = 2 * (inst.r(b) - inst.r(c - 1)) as Cost;
                let det2 = 2 * (u + inst.r(b) as Cost - inst.l(c) as Cost);
                let v = self.rows[c - 1][ns]
                    + span2 * ns as Cost
                    + det2 * (ns as Cost + inst.nl(c) as Cost)
                    + 2 * inst.in_detour_span_cost(c, b);
                if v == here {
                    chosen = Some(c);
                    break;
                }
            }
            let c = chosen.expect("some branch must reproduce an exact table cell");
            detours.push(Detour::new(c, b));
            b = c - 1;
        }
        detours
    }

    /// Exact optimal cost *and* schedule of `inst` through the table:
    /// sync to the instance, read the root cost, and reconstruct the
    /// detour list by exact value walk. Returns the `(appends, rebuilds)`
    /// the sync performed.
    pub fn opt_solve(&mut self, inst: &Instance) -> (Cost, Schedule, (u64, u64)) {
        let work = self.sync(inst);
        let cost = self.rows[inst.k() - 1][0] + virtual_lb(inst);
        let schedule = self.reconstruct(inst);
        (cost, schedule, work)
    }
}

/// Cap on tables kept per thread: past this the whole family is dropped
/// (the next solves rebuild). Keeps long multi-tape serving runs at a
/// bounded footprint without an LRU structure on the hot path.
const MAX_TABLES_PER_THREAD: usize = 64;

thread_local! {
    /// Per-thread table family, keyed by instance prefix fingerprint —
    /// one growth frontier per hot tape prefix. A coordinator drive
    /// worker is one thread, so this is exactly the per-worker state the
    /// serving path wants, with zero synchronization.
    static TABLES: RefCell<HashMap<u64, IncrementalTable>> = RefCell::new(HashMap::new());
    static SCRATCH: RefCell<DenseScratch> = RefCell::new(DenseScratch::default());
}

/// Fingerprint of the instance's *prefix identity*: tape geometry, `U`,
/// and the first requested file. Growing the batch never changes these,
/// so every growth step of one backlog lands on the same table (FNV-1a
/// over the fields; a collision only costs a rebuild, never correctness).
fn prefix_fingerprint(inst: &Instance) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let first = inst.files()[0];
    let mut h = OFFSET;
    for field in [inst.tape_len(), inst.u(), first.l, first.r, first.x] {
        h ^= field;
        h = h.wrapping_mul(PRIME);
    }
    h
}

fn with_table<R>(inst: &Instance, f: impl FnOnce(&mut IncrementalTable) -> R) -> R {
    TABLES.with(|tables| {
        let mut tables = tables.borrow_mut();
        let fp = prefix_fingerprint(inst);
        if tables.len() >= MAX_TABLES_PER_THREAD && !tables.contains_key(&fp) {
            tables.clear();
        }
        f(tables.entry(fp).or_default())
    })
}

/// Incremental dense SimpleDP backend: solves over a growing batch repair
/// the thread-local table keyed by the instance's prefix fingerprint
/// instead of re-solving from scratch, and schedules come from the exact
/// value walk over that table — bit-identical (debug-asserted) to the
/// scratch solver's.
#[derive(Debug, Clone, Copy, Default)]
pub struct IncrementalBackend;

impl SimpleDpBackend for IncrementalBackend {
    fn id(&self) -> &'static str {
        "incremental"
    }

    fn opt_cost(&self, inst: &Instance) -> Cost {
        let (cost, _, (appends, rebuilds)) = with_table(inst, |t| t.opt_solve(inst));
        count_incremental(appends, rebuilds);
        cost
    }

    fn opt_schedule(&self, inst: &Instance) -> Schedule {
        let (cost, schedule, (appends, rebuilds)) = with_table(inst, |t| t.opt_solve(inst));
        count_incremental(appends, rebuilds);
        if cfg!(debug_assertions) {
            // The serving-path bit-equality contract: cost AND detour
            // list must match the fresh scratch solve exactly.
            let (fresh_cost, fresh_schedule) =
                SCRATCH.with(|s| dense_solve_into(inst, &mut s.borrow_mut()));
            debug_assert_eq!(cost, fresh_cost, "incremental cost diverged from fresh solve");
            debug_assert_eq!(
                schedule, fresh_schedule,
                "incremental schedule diverged from fresh solve"
            );
        }
        schedule
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{Scheduler, SimpleDp};
    use crate::sim::evaluate;
    use crate::util::rng::Rng;

    fn grow_step(rng: &mut Rng, files: &mut Vec<ReqFile>) -> bool {
        // 1-in-4 steps mutate an existing file's multiplicity (a
        // non-append growth: the same batch gaining a duplicate request),
        // the rest append a fresh file after the current last one.
        if !files.is_empty() && rng.below(4) == 0 {
            let i = rng.below(files.len() as u64) as usize;
            files[i].x += 1;
            false
        } else {
            let prev_r = files.last().map(|f| f.r).unwrap_or(0);
            let l = prev_r + 1 + rng.below(5);
            let r = l + 1 + rng.below(8);
            files.push(ReqFile { l, r, x: 1 + rng.below(3) });
            true
        }
    }

    #[test]
    fn incremental_cost_is_bit_equal_on_random_grow_sequences() {
        // The property the ci gate leans on: along random grow sequences
        // (appends interleaved with multiplicity bumps), the incremental
        // cost equals the scratch solver's bit for bit, and BOTH paths
        // (append repair and full fallback) are exercised.
        let mut rng = Rng::new(0x1C41);
        let (mut appends, mut fallbacks) = (0u64, 0u64);
        for case in 0..25 {
            let mut table = IncrementalTable::new();
            let u = rng.below(9);
            let mut files: Vec<ReqFile> = Vec::new();
            for step in 0..18 {
                let appended = grow_step(&mut rng, &mut files);
                let inst = Instance::new(600, u, files.clone()).unwrap();
                let (cost, incremental) = table.opt_cost(&inst);
                assert_eq!(
                    cost,
                    SimpleDp::cost(&inst),
                    "case {case} step {step} (append: {appended})"
                );
                // The first step has no table to extend; later appends
                // must take the incremental path, mutations must not.
                if step > 0 {
                    assert_eq!(incremental, appended, "case {case} step {step}");
                }
                if incremental { appends += 1 } else { fallbacks += 1 };
            }
        }
        assert!(appends > 100, "append repair under-exercised: {appends}");
        assert!(fallbacks > 25, "fallback path under-exercised: {fallbacks}");
    }

    #[test]
    fn incremental_handles_clamp_heavy_multiplicities() {
        // Large multiplicities drive the skip-branch clamp hard (the
        // stale region the repair exists for): dominant x on the first,
        // middle, and appended file.
        let mut table = IncrementalTable::new();
        let seqs: Vec<Vec<ReqFile>> = vec![
            vec![
                ReqFile { l: 0, r: 5, x: 60 },
                ReqFile { l: 20, r: 30, x: 1 },
                ReqFile { l: 40, r: 45, x: 1 },
                ReqFile { l: 50, r: 52, x: 7 },
            ],
            vec![
                ReqFile { l: 3, r: 6, x: 1 },
                ReqFile { l: 20, r: 30, x: 60 },
                ReqFile { l: 40, r: 45, x: 1 },
                ReqFile { l: 90, r: 99, x: 2 },
            ],
            vec![
                ReqFile { l: 5, r: 6, x: 2 },
                ReqFile { l: 6, r: 30, x: 1 },
                ReqFile { l: 31, r: 32, x: 8 },
                ReqFile { l: 60, r: 61, x: 55 },
            ],
        ];
        for (i, seq) in seqs.iter().enumerate() {
            for step in 1..=seq.len() {
                let inst = Instance::new(200, 3, seq[..step].to_vec()).unwrap();
                let (cost, incremental) = table.opt_cost(&inst);
                assert_eq!(cost, SimpleDp::cost(&inst), "seq {i} step {step}");
                // Each sequence restarts (different first file): step 1
                // falls back, every later step is a pure append.
                assert_eq!(incremental, step > 1, "seq {i} step {step}");
            }
        }
    }

    #[test]
    fn incremental_repeated_instance_is_served_from_the_table() {
        let files = vec![
            ReqFile { l: 5, r: 6, x: 2 },
            ReqFile { l: 6, r: 30, x: 1 },
            ReqFile { l: 31, r: 32, x: 8 },
        ];
        let inst = Instance::new(100, 3, files).unwrap();
        let mut table = IncrementalTable::new();
        let (c1, first) = table.opt_cost(&inst);
        let (c2, second) = table.opt_cost(&inst);
        assert!(!first, "first solve must rebuild");
        assert!(second, "identical re-solve must reuse the table");
        assert_eq!(c1, c2);
        assert_eq!(c1, SimpleDp::cost(&inst));
        // A different U on the same files must NOT reuse the table.
        let (c3, third) = table.opt_cost(&inst.with_u(9));
        assert!(!third);
        assert_eq!(c3, SimpleDp::cost(&inst.with_u(9)));
    }

    #[test]
    fn incremental_schedules_are_bit_identical_to_the_fresh_solve() {
        // The serving-path contract: along random grow sequences the
        // value-walk reconstruction must reproduce dense_solve_into's
        // detour list exactly — same decisions, not merely same cost.
        let mut rng = Rng::new(0x51EA);
        let mut scratch = DenseScratch::default();
        for case in 0..15 {
            let mut table = IncrementalTable::new();
            let u = rng.below(7);
            let mut files: Vec<ReqFile> = Vec::new();
            for step in 0..14 {
                grow_step(&mut rng, &mut files);
                let inst = Instance::new(500, u, files.clone()).unwrap();
                let (cost, sched, _) = table.opt_solve(&inst);
                let (fresh_cost, fresh_sched) = dense_solve_into(&inst, &mut scratch);
                assert_eq!(cost, fresh_cost, "case {case} step {step}: cost");
                assert_eq!(sched, fresh_sched, "case {case} step {step}: schedule");
            }
        }
    }

    #[test]
    fn opt_solve_reuses_the_longest_stored_prefix() {
        let f = |l: u64, r: u64, x: u64| ReqFile { l, r, x };
        let files =
            vec![f(2, 4, 2), f(10, 30, 5), f(33, 34, 1), f(50, 80, 4), f(90, 99, 2)];
        let mut table = IncrementalTable::new();
        let inst = |k: usize| Instance::new(110, 3, files[..k].to_vec()).unwrap();
        // Fresh: one rebuild (first file) plus one append per later file.
        let (_, _, work) = table.opt_solve(&inst(3));
        assert_eq!(work, (2, 1));
        // Verbatim re-solve: pure table hit, no work.
        let (_, _, work) = table.opt_solve(&inst(3));
        assert_eq!(work, (0, 0));
        // Growth by two files: exactly two append repairs.
        let (_, _, work) = table.opt_solve(&inst(5));
        assert_eq!(work, (2, 0));
        // A shrink cannot reuse rows (the clamp column moved): restart.
        let (_, _, work) = table.opt_solve(&inst(2));
        assert_eq!(work, (1, 1));
        // A different U restarts even on identical files.
        let other = Instance::new(110, 9, files[..2].to_vec()).unwrap();
        let (cost, sched, work) = table.opt_solve(&other);
        assert_eq!(work, (1, 1));
        assert_eq!(cost, SimpleDp::cost(&other));
        assert_eq!(evaluate(&other, &sched).cost, cost);
    }

    #[test]
    fn thread_deltas_attribute_backend_work_to_the_calling_thread() {
        // Each test runs on its own thread, but drain defensively anyway.
        let _ = take_thread_incremental_stats();
        let b = IncrementalBackend;
        let files = vec![
            ReqFile { l: 1, r: 3, x: 1 },
            ReqFile { l: 7, r: 9, x: 2 },
            ReqFile { l: 12, r: 20, x: 1 },
        ];
        let inst = Instance::new(64, 2, files).unwrap();
        let _ = b.opt_schedule(&inst);
        assert_eq!(
            take_thread_incremental_stats(),
            (2, 1),
            "k = 3 fresh: one rebuild plus two appends"
        );
        assert_eq!(take_thread_incremental_stats(), (0, 0), "drained");
        let _ = b.opt_schedule(&inst);
        assert_eq!(take_thread_incremental_stats(), (0, 0), "verbatim re-solve is free");
    }

    #[test]
    fn incremental_backend_serves_exact_costs_and_schedules() {
        let b = IncrementalBackend;
        assert_eq!(b.id(), "incremental");
        let (a0, f0) = incremental_stats();
        let mut files = vec![ReqFile { l: 2, r: 4, x: 2 }];
        let mut last = None;
        for add in [(10u64, 30u64, 5u64), (33, 34, 1), (50, 80, 4), (90, 99, 2)] {
            files.push(ReqFile { l: add.0, r: add.1, x: add.2 });
            let inst = Instance::new(110, 0, files.clone()).unwrap();
            let expected = SimpleDp::cost(&inst);
            assert_eq!(b.opt_cost(&inst), expected);
            let sched = b.opt_schedule(&inst);
            assert_eq!(evaluate(&inst, &sched).cost, expected);
            last = Some(inst);
        }
        let (a1, f1) = incremental_stats();
        assert!(a1 > a0, "appends must be counted");
        assert!(f1 > f0, "the first solve counts as a fallback");
        // The schedule detour list matches the sparse solver's cost too.
        let inst = last.unwrap();
        let sparse = evaluate(&inst, &SimpleDp.schedule(&inst)).cost;
        assert_eq!(b.opt_cost(&inst), sparse);
    }
}
