//! PJRT runtime: load and execute the AOT-compiled XLA artifacts produced
//! by `python/compile/aot.py` (`make artifacts`).
//!
//! Python runs once at build time; this module is how the Rust hot path
//! runs the resulting computation. The interchange format is **HLO text**
//! (`artifacts/*.hlo.txt`): jax ≥ 0.5 emits protos with 64-bit instruction
//! ids that the crate's bundled XLA rejects, while the text parser
//! reassigns ids and round-trips cleanly.
//!
//! - [`Engine`] — PJRT CPU client + artifact cache (compile once per
//!   artifact, execute many times).
//! - [`XlaSimpleDp`] — the accelerated SimpleDP evaluation backend: pads an
//!   instance into a `(K, NS)` shape bucket, runs the dense wavefront
//!   artifact, and reconstructs the detour list in Rust from the returned
//!   table values (cross-validated against the exact `i128` implementation
//!   in `sched::simpledp_dense`).

mod engine;
mod xla_simpledp;

pub use engine::{Engine, RuntimeError};
pub use xla_simpledp::{ShapeBucket, XlaSimpleDp, DEFAULT_BUCKETS, POS_SCALE};

/// Default artifact directory (relative to the repo root / working dir).
pub const ARTIFACT_DIR: &str = "artifacts";
