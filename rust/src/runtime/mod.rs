//! Pluggable SimpleDP evaluation backends.
//!
//! The dense SimpleDP wavefront (the `(K, NS)` table of §4.5, evaluated
//! bottom-up) has two interchangeable execution engines behind the
//! [`SimpleDpBackend`] trait:
//!
//! - [`DenseBackend`] — the exact pure-Rust `i128` implementation in
//!   [`crate::sched::simpledp_dense`]. Always available; the default.
//! - [`IncrementalBackend`] — the same dense wavefront, but solves over a
//!   *growing* batch repair the stored per-prefix table instead of
//!   re-solving from scratch, and schedules come from an exact value walk
//!   over that table. Opt-in by name (`--backend incremental`); costs and
//!   detour lists stay bit-equal to [`DenseBackend`] (debug-asserted on
//!   the serving path), so `serve`/`replay --backend incremental` change
//!   speed, never output.
//! - `XlaSimpleDp` — PJRT execution of the AOT-compiled artifacts produced
//!   by `python/compile/aot.py` (`make artifacts`). Compiled in only with
//!   `--features xla`; instances that fit no artifact bucket fall back to
//!   the exact sparse solver.
//!
//! [`BackendPolicy`] adapts any backend into a [`crate::sched::Scheduler`]
//! so the coordinator, the CLI (`--backend dense|xla`) and the bench
//! harness can treat backends as ordinary scheduling policies.

mod dense;
#[cfg(feature = "xla")]
mod engine;
mod incremental;
#[cfg(feature = "xla")]
mod xla_simpledp;

pub use dense::{dense_cache_stats, DenseBackend};
pub use incremental::{
    incremental_stats, take_thread_incremental_stats, IncrementalBackend, IncrementalTable,
};
#[cfg(feature = "xla")]
pub use engine::{Engine, RuntimeError};
#[cfg(feature = "xla")]
pub use xla_simpledp::{ShapeBucket, XlaSimpleDp, DEFAULT_BUCKETS, POS_SCALE};

use std::sync::Arc;

use crate::model::{Cost, Instance};
use crate::sched::{Schedule, Scheduler};

/// Default artifact directory (relative to the repo root / working dir).
pub const ARTIFACT_DIR: &str = "artifacts";

/// An execution engine for the disjoint-detour (SimpleDP) optimum.
///
/// Implementations must return the *exact* optimal disjoint-detour cost
/// and a schedule achieving it for every valid instance — accelerated
/// backends are expected to fall back to a pure-Rust path for inputs they
/// cannot handle (missing artifacts, no fitting shape bucket), never to
/// approximate.
pub trait SimpleDpBackend: Send + Sync {
    /// Stable identifier used for CLI selection and report labels
    /// (`"dense"`, `"incremental"`, `"xla"`).
    fn id(&self) -> &'static str;

    /// Optimal disjoint-detour cost (including `VirtualLB`).
    fn opt_cost(&self, inst: &Instance) -> Cost;

    /// A schedule achieving [`SimpleDpBackend::opt_cost`].
    fn opt_schedule(&self, inst: &Instance) -> Schedule;

    /// Whether this backend actually accelerates `inst` (as opposed to
    /// serving it through a fallback path). Diagnostics only.
    fn accelerates(&self, _inst: &Instance) -> bool {
        false
    }
}

/// Adapter: any [`SimpleDpBackend`] as a [`Scheduler`] policy.
pub struct BackendPolicy {
    backend: Arc<dyn SimpleDpBackend>,
}

impl BackendPolicy {
    pub fn new(backend: Arc<dyn SimpleDpBackend>) -> BackendPolicy {
        BackendPolicy { backend }
    }

    /// The wrapped backend.
    pub fn backend(&self) -> &dyn SimpleDpBackend {
        self.backend.as_ref()
    }
}

impl Scheduler for BackendPolicy {
    fn name(&self) -> String {
        format!("SimpleDP[{}]", self.backend.id())
    }

    fn schedule(&self, inst: &Instance) -> Schedule {
        self.backend.opt_schedule(inst)
    }
}

/// The backend used when nothing is configured: pure-Rust dense.
pub fn default_backend() -> Arc<dyn SimpleDpBackend> {
    Arc::new(DenseBackend)
}

/// Look a backend up by (case-insensitive) id: `"dense"` and
/// `"incremental"` are always available; `"xla"` requires the `xla`
/// feature and a constructible PJRT engine. Errors carry a user-facing
/// explanation. (`incremental` is name-selectable only: it stays out of
/// [`available_backends`] because it is the *same* exact engine as dense
/// with a different re-solve strategy, not an additional backend to sweep
/// in comparisons.)
pub fn backend_by_name(name: &str) -> Result<Arc<dyn SimpleDpBackend>, String> {
    let n = name.to_ascii_lowercase();
    if n == "dense" {
        return Ok(Arc::new(DenseBackend));
    }
    if n == "incremental" {
        return Ok(Arc::new(IncrementalBackend));
    }
    if n == "xla" {
        #[cfg(feature = "xla")]
        {
            return match XlaSimpleDp::new(ARTIFACT_DIR) {
                Ok(b) => Ok(Arc::new(b)),
                Err(e) => Err(format!("xla backend unavailable: {e}")),
            };
        }
        #[cfg(not(feature = "xla"))]
        {
            return Err(
                "backend `xla` requires building with `--features xla`".to_string()
            );
        }
    }
    Err(format!("unknown backend `{name}` (known: dense, incremental, xla)"))
}

/// Every backend constructible in this build: dense always, xla when the
/// feature is compiled in and the engine constructs (artifact presence is
/// *not* required — an artifact-less xla backend serves through its
/// fallback path).
pub fn available_backends() -> Vec<Arc<dyn SimpleDpBackend>> {
    #[allow(unused_mut)] // mutated only when the xla feature is compiled in
    let mut backends: Vec<Arc<dyn SimpleDpBackend>> = vec![Arc::new(DenseBackend)];
    #[cfg(feature = "xla")]
    if let Ok(b) = XlaSimpleDp::new(ARTIFACT_DIR) {
        backends.push(Arc::new(b));
    }
    backends
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ReqFile;
    use crate::sched::{Scheduler, SimpleDp};
    use crate::sim::evaluate;

    fn inst() -> Instance {
        Instance::new(
            100,
            3,
            vec![
                ReqFile { l: 5, r: 6, x: 2 },
                ReqFile { l: 6, r: 30, x: 1 },
                ReqFile { l: 31, r: 32, x: 8 },
            ],
        )
        .unwrap()
    }

    #[test]
    fn default_backend_is_dense() {
        assert_eq!(default_backend().id(), "dense");
    }

    #[test]
    fn backend_by_name_resolves_dense_case_insensitively() {
        assert_eq!(backend_by_name("dense").unwrap().id(), "dense");
        assert_eq!(backend_by_name("Dense").unwrap().id(), "dense");
        assert_eq!(backend_by_name("Incremental").unwrap().id(), "incremental");
        let err = backend_by_name("nope").unwrap_err();
        assert!(err.contains("unknown backend"));
        assert!(err.contains("incremental"), "error must list the known ids: {err}");
    }

    #[test]
    fn incremental_backend_is_selectable_but_not_swept() {
        // `available_backends` drives comparison sweeps; incremental is
        // the same exact engine as dense, so it must stay name-only.
        let policy = BackendPolicy::new(backend_by_name("incremental").unwrap());
        assert_eq!(policy.name(), "SimpleDP[incremental]");
        assert!(available_backends().iter().all(|b| b.id() != "incremental"));
        let i = inst();
        assert_eq!(policy.backend().opt_cost(&i), SimpleDp::cost(&i));
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn xla_backend_errors_without_the_feature() {
        let err = backend_by_name("xla").unwrap_err();
        assert!(err.contains("--features xla"), "unhelpful error: {err}");
    }

    #[test]
    fn backend_policy_is_an_exact_simpledp_scheduler() {
        let policy = BackendPolicy::new(default_backend());
        assert_eq!(policy.name(), "SimpleDP[dense]");
        assert_eq!(policy.backend().id(), "dense");
        let i = inst();
        let via_policy = evaluate(&i, &policy.schedule(&i)).cost;
        let via_sparse = evaluate(&i, &SimpleDp.schedule(&i)).cost;
        assert_eq!(via_policy, via_sparse);
        assert_eq!(policy.backend().opt_cost(&i), via_sparse);
    }

    #[test]
    fn available_backends_lead_with_dense() {
        let backends = available_backends();
        assert!(!backends.is_empty());
        assert_eq!(backends[0].id(), "dense");
    }
}
