//! XLA-accelerated SimpleDP evaluation backend.
//!
//! The `python/compile` pipeline AOT-lowers the dense SimpleDP wavefront
//! (L2 `lax.scan` over files, each step running the L1 Pallas kernel) into
//! `artifacts/simpledp_{K}x{NS}.hlo.txt` for a few static shape buckets.
//! This module pads an [`Instance`] into the smallest fitting bucket, runs
//! the artifact through [`Engine`], and reconstructs the optimal
//! disjoint-detour schedule in Rust from the returned table values.
//!
//! Numerics: the artifact computes in f64 over positions rescaled by
//! [`POS_SCALE`] (bytes → GB); the exact `i128` twin lives in
//! [`crate::sched::simpledp_dense`] and the two are asserted to agree to
//! ≤ 1e-9 relative in tests.

use crate::model::{virtual_lb, Cost, Instance};
use crate::sched::simpledp_dense::reconstruct_from_values;
use crate::sched::{Schedule, Scheduler, SimpleDp};

use super::engine::{Engine, RuntimeError};
use super::SimpleDpBackend;

/// Position rescale factor applied before entering f64 (bytes → GB keeps
/// products comfortably inside the 53-bit mantissa).
pub const POS_SCALE: f64 = 1e9;

/// A static `(K, NS)` artifact shape: up to `K` requested files, up to
/// `NS − 1` total requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShapeBucket {
    pub k: usize,
    pub ns: usize,
}

impl ShapeBucket {
    /// Artifact name for this bucket.
    pub fn artifact(&self) -> String {
        format!("simpledp_{}x{}", self.k, self.ns)
    }

    /// Whether an instance fits this bucket.
    pub fn fits(&self, inst: &Instance) -> bool {
        inst.k() <= self.k && (inst.n() as usize) < self.ns
    }
}

/// The buckets built by `make artifacts` (see `python/compile/aot.py`).
pub const DEFAULT_BUCKETS: &[ShapeBucket] = &[
    ShapeBucket { k: 16, ns: 128 },
    ShapeBucket { k: 64, ns: 1024 },
    ShapeBucket { k: 128, ns: 4096 },
];

/// XLA SimpleDP backend. Implements [`Scheduler`]; instances that fit no
/// available bucket fall back to the exact Rust [`SimpleDp`].
pub struct XlaSimpleDp {
    engine: Engine,
    buckets: Vec<ShapeBucket>,
}

impl XlaSimpleDp {
    /// Create over an artifact directory, keeping only buckets whose
    /// artifact file actually exists.
    pub fn new(dir: impl AsRef<std::path::Path>) -> Result<XlaSimpleDp, RuntimeError> {
        Self::with_buckets(dir, DEFAULT_BUCKETS)
    }

    /// Create with a custom bucket list (still filtered by availability).
    pub fn with_buckets(
        dir: impl AsRef<std::path::Path>,
        buckets: &[ShapeBucket],
    ) -> Result<XlaSimpleDp, RuntimeError> {
        let engine = Engine::new(dir)?;
        let buckets = buckets
            .iter()
            .copied()
            .filter(|b| engine.has_artifact(&b.artifact()))
            .collect();
        Ok(XlaSimpleDp { engine, buckets })
    }

    /// Buckets with a compiled artifact available.
    pub fn buckets(&self) -> &[ShapeBucket] {
        &self.buckets
    }

    /// Smallest available bucket fitting `inst`.
    pub fn bucket_for(&self, inst: &Instance) -> Option<ShapeBucket> {
        self.buckets
            .iter()
            .copied()
            .filter(|b| b.fits(inst))
            .min_by_key(|b| b.k * b.ns)
    }

    /// Run the dense wavefront artifact for `inst`, returning the
    /// **descaled** table `T[b, ns]` as a closure plus the bucket used.
    pub fn table(
        &self,
        inst: &Instance,
    ) -> Result<(Vec<f64>, ShapeBucket), RuntimeError> {
        let bucket = self.bucket_for(inst).ok_or_else(|| {
            RuntimeError::MissingArtifact(
                self.engine.dir().join("<no fitting bucket>"),
            )
        })?;
        let (kb, nsb) = (bucket.k, bucket.ns);
        let k = inst.k();
        // Pad per-file arrays: zero-size zero-request files parked at the
        // right end. Rows ≥ k of the result are junk; rows < k only ever
        // consult columns c ≤ b < k, so padding cannot leak in.
        let last_r = inst.r(k - 1) as f64 / POS_SCALE;
        let mut l = vec![last_r; kb];
        let mut r = vec![last_r; kb];
        let mut x = vec![0.0f64; kb];
        for i in 0..k {
            l[i] = inst.l(i) as f64 / POS_SCALE;
            r[i] = inst.r(i) as f64 / POS_SCALE;
            x[i] = inst.x(i) as f64;
        }
        let u = [inst.u() as f64 / POS_SCALE];
        let table = self.engine.run_f64(
            &bucket.artifact(),
            &[
                (&l, &[kb as i64]),
                (&r, &[kb as i64]),
                (&x, &[kb as i64]),
                (&u, &[]),
            ],
        )?;
        debug_assert_eq!(table.len(), kb * nsb);
        Ok((table, bucket))
    }

    /// Optimal disjoint-detour cost via the artifact (descaled, rounded to
    /// the nearest integer cost unit).
    pub fn cost(&self, inst: &Instance) -> Result<Cost, RuntimeError> {
        let (table, bucket) = self.table(inst)?;
        let root = table[(inst.k() - 1) * bucket.ns] * POS_SCALE;
        Ok(root.round() as Cost + virtual_lb(inst))
    }

    /// Schedule via the artifact; `Err` if no bucket fits.
    pub fn try_schedule(&self, inst: &Instance) -> Result<Schedule, RuntimeError> {
        let (table, bucket) = self.table(inst)?;
        let ns_cap = bucket.ns - 1;
        // Descale back to byte units: `reconstruct_from_values` re-derives
        // the branch costs from the instance's raw (byte) geometry.
        let at = move |b: usize, ns: usize| table[b * bucket.ns + ns.min(ns_cap)] * POS_SCALE;
        Ok(reconstruct_from_values(inst, &at, 1e-6))
    }
}

impl Scheduler for XlaSimpleDp {
    fn name(&self) -> String {
        "SimpleDP[xla]".into()
    }

    fn schedule(&self, inst: &Instance) -> Schedule {
        match self.try_schedule(inst) {
            Ok(s) => s,
            Err(_) => SimpleDp.schedule(inst), // no bucket / artifact: exact path
        }
    }
}

impl SimpleDpBackend for XlaSimpleDp {
    fn id(&self) -> &'static str {
        "xla"
    }

    fn opt_cost(&self, inst: &Instance) -> Cost {
        // The artifact path is fallible (no bucket, missing artifact,
        // engine error); fall back to the exact sparse solver, never fail.
        match XlaSimpleDp::cost(self, inst) {
            Ok(c) => c,
            Err(_) => SimpleDp::cost(inst),
        }
    }

    fn opt_schedule(&self, inst: &Instance) -> Schedule {
        match self.try_schedule(inst) {
            Ok(s) => s,
            Err(_) => SimpleDp.schedule(inst),
        }
    }

    fn accelerates(&self, inst: &Instance) -> bool {
        self.bucket_for(inst).is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ReqFile;
    use crate::sched::simpledp_dense::dense_cost;
    use crate::sim::evaluate;

    fn inst(u: u64, files: &[(u64, u64, u64)], m: u64) -> Instance {
        Instance::new(m, u, files.iter().map(|&(l, r, x)| ReqFile { l, r, x }).collect())
            .unwrap()
    }

    fn backend() -> Option<XlaSimpleDp> {
        // Artifacts live at the repo root; tests run from the crate root.
        let b = XlaSimpleDp::new(super::super::ARTIFACT_DIR).ok()?;
        if b.buckets().is_empty() {
            eprintln!("skipping XLA tests: no artifacts (run `make artifacts`)");
            None
        } else {
            Some(b)
        }
    }

    fn fixtures() -> Vec<Instance> {
        vec![
            inst(0, &[(0, 5, 1), (10, 12, 9), (40, 60, 1)], 80),
            inst(7, &[(0, 5, 1), (10, 12, 9), (40, 60, 1)], 80),
            inst(3, &[(5, 6, 2), (6, 30, 1), (31, 32, 8), (60, 61, 3)], 100),
            inst(
                11,
                &[(0, 4, 3), (8, 20, 1), (25, 26, 14), (40, 70, 2), (90, 95, 6)],
                120,
            ),
        ]
    }

    #[test]
    fn bucket_selection() {
        let buckets = [
            ShapeBucket { k: 16, ns: 128 },
            ShapeBucket { k: 64, ns: 1024 },
        ];
        let small = inst(0, &[(0, 5, 1), (10, 12, 9)], 20);
        assert!(buckets[0].fits(&small));
        let many_reqs = inst(0, &[(0, 5, 200), (10, 12, 9)], 20);
        assert!(!buckets[0].fits(&many_reqs), "n=209 exceeds ns=128");
        assert!(buckets[1].fits(&many_reqs));
    }

    #[test]
    fn xla_cost_matches_exact_dense() {
        let Some(b) = backend() else { return };
        for i in fixtures() {
            let xla = b.cost(&i).expect("fixture fits the smallest bucket");
            let exact = dense_cost(&i);
            assert_eq!(xla, exact, "instance {:?}", i);
        }
    }

    #[test]
    fn xla_schedule_achieves_exact_cost() {
        let Some(b) = backend() else { return };
        for i in fixtures() {
            let sched = b.try_schedule(&i).unwrap();
            assert_eq!(evaluate(&i, &sched).cost, dense_cost(&i));
        }
    }

    #[test]
    fn scheduler_falls_back_without_bucket() {
        // A backend over an empty dir has no buckets: schedule() must
        // still work via the exact Rust path.
        let dir = std::env::temp_dir().join("tapesched_empty_artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        let b = XlaSimpleDp::new(&dir).expect("engine without artifacts");
        assert!(b.buckets().is_empty());
        let i = inst(3, &[(5, 6, 2), (6, 30, 1), (31, 32, 8)], 100);
        let sched = b.schedule(&i);
        assert_eq!(evaluate(&i, &sched).cost, dense_cost(&i));
    }
}
