//! In-crate micro/macro-benchmark framework.
//!
//! The offline crate registry carries no criterion, so `cargo bench`
//! binaries (declared with `harness = false`) use this framework instead:
//! warmup, a fixed-duration measurement loop, robust summary statistics
//! (median, p10/p90), and text + CSV reporting.

use std::time::{Duration, Instant};

use crate::util::stats::percentile_sorted;

/// Configuration for one benchmark run.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Warmup duration before measuring.
    pub warmup: Duration,
    /// Target measurement duration.
    pub measure: Duration,
    /// Hard cap on iterations (for very slow benchmarks).
    pub max_iters: u32,
    /// Minimum number of measured iterations.
    pub min_iters: u32,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(200),
            measure: Duration::from_secs(1),
            max_iters: 10_000,
            min_iters: 5,
        }
    }
}

impl BenchConfig {
    /// A faster profile for slow end-to-end benchmarks.
    pub fn quick() -> BenchConfig {
        BenchConfig {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(400),
            max_iters: 200,
            min_iters: 3,
        }
    }

    /// The `--smoke` profile: one measured iteration, no warmup. Numbers
    /// are meaningless as benchmarks; the point is that the whole bench
    /// binary *runs* in seconds so CI can gate on it (`make bench-smoke`).
    pub fn smoke() -> BenchConfig {
        BenchConfig {
            warmup: Duration::ZERO,
            measure: Duration::ZERO,
            max_iters: 1,
            min_iters: 1,
        }
    }
}

/// True when the bench binary should take its fast path: invoked with
/// `--smoke` (after `cargo bench --bench NAME -- --smoke`) or with
/// `TAPESCHED_SMOKE=1` in the environment.
pub fn smoke_requested() -> bool {
    std::env::args().any(|a| a == "--smoke")
        || std::env::var("TAPESCHED_SMOKE").map_or(false, |v| v == "1")
}

/// Summary of one benchmark: all times in seconds per iteration.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub median: f64,
    pub mean: f64,
    pub p10: f64,
    pub p90: f64,
}

impl BenchResult {
    /// Human format with auto-scaled units.
    pub fn pretty(&self) -> String {
        format!(
            "{:<44} {:>12}  (p10 {:>10}, p90 {:>10}, {} iters)",
            self.name,
            fmt_seconds(self.median),
            fmt_seconds(self.p10),
            fmt_seconds(self.p90),
            self.iters
        )
    }

    /// CSV row: `name,iters,median_s,mean_s,p10_s,p90_s`.
    pub fn csv_row(&self) -> String {
        format!(
            "{},{},{:.9},{:.9},{:.9},{:.9}",
            self.name, self.iters, self.median, self.mean, self.p10, self.p90
        )
    }
}

/// Format seconds with an auto-scaled unit.
pub fn fmt_seconds(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark a closure. The closure's return value is passed through
/// [`std::hint::black_box`] to keep the optimizer honest.
pub fn bench<T>(name: &str, cfg: &BenchConfig, mut f: impl FnMut() -> T) -> BenchResult {
    // Warmup.
    let wstart = Instant::now();
    while wstart.elapsed() < cfg.warmup {
        std::hint::black_box(f());
    }
    // Measure.
    let mut samples = Vec::new();
    let mstart = Instant::now();
    while (mstart.elapsed() < cfg.measure && samples.len() < cfg.max_iters as usize)
        || samples.len() < cfg.min_iters as usize
    {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    BenchResult {
        name: name.to_string(),
        iters: samples.len() as u32,
        median: percentile_sorted(&samples, 50.0),
        mean: samples.iter().sum::<f64>() / samples.len() as f64,
        p10: percentile_sorted(&samples, 10.0),
        p90: percentile_sorted(&samples, 90.0),
    }
}

/// A suite accumulates results and prints a report at the end.
#[derive(Debug, Default)]
pub struct Suite {
    pub results: Vec<BenchResult>,
}

impl Suite {
    pub fn new() -> Suite {
        Suite::default()
    }

    /// Run and record one benchmark, echoing the result line immediately.
    pub fn run<T>(&mut self, name: &str, cfg: &BenchConfig, f: impl FnMut() -> T) {
        let r = bench(name, cfg, f);
        println!("{}", r.pretty());
        self.results.push(r);
    }

    /// Record an externally produced result (e.g. a one-shot measurement).
    pub fn record(&mut self, r: BenchResult) {
        println!("{}", r.pretty());
        self.results.push(r);
    }

    /// Full CSV of all results.
    pub fn csv(&self) -> String {
        let mut out = String::from("name,iters,median_s,mean_s,p10_s,p90_s\n");
        for r in &self.results {
            out.push_str(&r.csv_row());
            out.push('\n');
        }
        out
    }

    /// Write the CSV next to the target dir (best effort; benches also
    /// print everything to stdout).
    pub fn write_csv(&self, path: &str) {
        if let Err(e) = std::fs::write(path, self.csv()) {
            eprintln!("warning: could not write {path}: {e}");
        }
    }
}

/// Measure a single execution (for expensive runs where iteration is
/// impossible); produces a 1-iteration [`BenchResult`].
pub fn once<T>(name: &str, f: impl FnOnce() -> T) -> (T, BenchResult) {
    let t0 = Instant::now();
    let v = std::hint::black_box(f());
    let s = t0.elapsed().as_secs_f64();
    (
        v,
        BenchResult {
            name: name.to_string(),
            iters: 1,
            median: s,
            mean: s,
            p10: s,
            p90: s,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_cfg() -> BenchConfig {
        BenchConfig {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(10),
            max_iters: 1_000,
            min_iters: 3,
        }
    }

    #[test]
    fn bench_produces_ordered_percentiles() {
        let r = bench("noop", &fast_cfg(), || 1 + 1);
        assert!(r.iters >= 3);
        assert!(r.p10 <= r.median && r.median <= r.p90);
        assert!(r.median >= 0.0);
    }

    #[test]
    fn min_iters_enforced_for_slow_bodies() {
        let cfg = BenchConfig {
            warmup: Duration::ZERO,
            measure: Duration::from_millis(1),
            max_iters: 100,
            min_iters: 4,
        };
        let r = bench("sleepy", &cfg, || std::thread::sleep(Duration::from_millis(2)));
        assert!(r.iters >= 4);
    }

    #[test]
    fn smoke_profile_is_single_iteration() {
        let r = bench("noop", &BenchConfig::smoke(), || 1 + 1);
        assert_eq!(r.iters, 1);
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_seconds(2.5), "2.500 s");
        assert_eq!(fmt_seconds(0.0025), "2.500 ms");
        assert_eq!(fmt_seconds(2.5e-6), "2.500 µs");
        assert_eq!(fmt_seconds(2.5e-8), "25.0 ns");
    }

    #[test]
    fn suite_csv() {
        let mut s = Suite::new();
        s.run("a", &fast_cfg(), || 42);
        let (v, r) = once("b", || 7);
        assert_eq!(v, 7);
        s.record(r);
        let csv = s.csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.lines().nth(2).unwrap().starts_with("b,1,"));
    }
}
