//! Bench E1–E3 — regenerate the Figure 14/15/16 performance profiles and
//! report the end-to-end evaluation cost per U scenario.
//!
//! `cargo bench --bench profiles [-- <n_tapes> <max_k>]`
//! Writes `results/fig1{4,5,6}.csv` like `tapesched figures` and prints
//! the headline profile values the paper quotes in §5.3.

use tapesched::analysis::report::run_evaluation;
use tapesched::bench::{once, smoke_requested, Suite};
use tapesched::dataset::{generate_dataset, GeneratorConfig};
use tapesched::sched::paper_schedulers;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    // Smoke: the pinned minimum tape (n_req = 31) must survive the max_k
    // filter or the profile builder has zero instances.
    let (default_tapes, default_max_k) = if smoke_requested() { (6, 35) } else { (24, 55) };
    let n_tapes: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(default_tapes);
    let max_k: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(default_max_k);

    let ds = generate_dataset(&GeneratorConfig { n_tapes, ..Default::default() });
    let [u0, u_half, u_avg] = ds.paper_u_values();
    let schedulers = paper_schedulers();
    std::fs::create_dir_all("results").ok();

    let mut suite = Suite::new();
    for (fig, u) in [("fig14", u0), ("fig15", u_avg), ("fig16", u_half)] {
        let (table, r) = once(&format!("evaluation/{fig}(U={u})"), || {
            run_evaluation(&ds, &schedulers, u, Some(max_k))
        });
        suite.record(r);
        std::fs::write(format!("results/{fig}.csv"), table.profiles_csv("DP")).ok();

        // Headline checks from §5.3, printed for eyeballing:
        let curves = table.profiles("DP");
        let at = |name: &str, tau: f64| {
            curves
                .iter()
                .find(|c| c.algorithm == name)
                .map(|c| c.at(tau) * 100.0)
                .unwrap_or(f64::NAN)
        };
        println!(
            "  {fig}: SimpleDP ≤1% of OPT on {:.0}% of instances; \
             NFGS ≤2.5% on {:.0}%; NoDetour >10% on {:.0}%",
            at("SimpleDP", 1.0),
            at("NFGS", 2.5),
            100.0 - at("NoDetour", 10.0),
        );
    }
    suite.write_csv("bench_profiles.csv");
    println!("profiles → results/fig14.csv, fig15.csv, fig16.csv");
}
