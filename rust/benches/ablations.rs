//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. LogDP's λ — solution quality vs compute time on a median instance
//!    (the paper's "λ can be adjusted to trade accuracy for time").
//! 2. The coordinator's batch window — batching is what turns random
//!    arrivals into LTSP instances worth optimizing; a zero window
//!    degenerates to per-request FIFO service.
//! 3. U-turn penalty sweep — how the optimal structure (number of
//!    detours) and the DP/GS gap react as U grows (Figs 14→16 trend).

use std::sync::Arc;
use std::time::Instant;

use tapesched::bench::{bench, smoke_requested, BenchConfig, Suite};
use tapesched::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, ReadRequest};
use tapesched::dataset::{generate_dataset, GeneratorConfig};
use tapesched::sched::{scheduler_by_name, Dp, Gs, LogDp, Scheduler};
use tapesched::sim::{evaluate, DriveParams};
use tapesched::util::rng::Rng;

/// Small-marginal dataset for `--smoke`: the pinned extreme tapes keep the
/// n_req filters below satisfiable (tape 1 lands at n_req = 90, tape 0 at
/// n_req = 35).
fn smoke_dataset() -> GeneratorConfig {
    GeneratorConfig {
        n_tapes: 12,
        nf: (40, 60.0, 70.0, 150),
        nreq: (35, 60.0, 65.0, 90),
        n: (60, 150.0, 170.0, 300),
        ..Default::default()
    }
}

fn main() {
    let smoke = smoke_requested();
    let mut suite = Suite::new();
    let ds = if smoke {
        generate_dataset(&smoke_dataset())
    } else {
        generate_dataset(&GeneratorConfig::default())
    };
    let [_, u_half, _] = ds.paper_u_values();
    let bench_cfg = if smoke { BenchConfig::smoke() } else { BenchConfig::quick() };

    // --- 1. LogDP λ sweep: quality vs time -------------------------------
    // A mid-size tape (exact DP still feasible for the reference).
    let tape = ds
        .tapes
        .iter()
        .filter(|t| (60..=90).contains(&t.n_req()))
        .min_by_key(|t| t.n_req())
        .expect("mid-size tape exists");
    let inst = tape.instance(u_half).unwrap();
    println!(
        "=== LogDP λ ablation on {} (n_req={}, n={}) ===",
        tape.tape.name,
        inst.k(),
        inst.n()
    );
    let opt = evaluate(&inst, &Dp.schedule(&inst)).cost;
    println!("{:>8} {:>14} {:>10} {:>12}", "λ", "cost", "overhead", "median time");
    for lambda in [0.5, 1.0, 2.0, 5.0, 10.0] {
        let algo = LogDp::new(lambda);
        let r = bench(
            &format!("logdp_lambda/{lambda}"),
            &bench_cfg,
            || algo.schedule(&inst),
        );
        let cost = evaluate(&inst, &algo.schedule(&inst)).cost;
        println!(
            "{lambda:>8} {cost:>14} {:>9.3}% {:>12}",
            (cost - opt) as f64 / opt as f64 * 100.0,
            tapesched::bench::fmt_seconds(r.median)
        );
        suite.results.push(r);
    }

    // --- 2. batch-window ablation ----------------------------------------
    let n_reqs: u64 = if smoke { 500 } else { 3_000 };
    let n_tapes = ds.tapes.len().min(24);
    let windows: &[u64] = if smoke { &[0, 10] } else { &[0, 2, 10, 50] };
    println!("\n=== batch-window ablation (SimpleDP, 4 drives, {n_reqs} reqs) ===");
    println!("{:>10} {:>9} {:>14} {:>14}", "window", "batches", "mean svc (s)", "wall (s)");
    for &window_ms in windows {
        let t0 = Instant::now();
        let coord = Coordinator::start(
            CoordinatorConfig {
                n_drives: 4,
                batcher: BatcherConfig {
                    window: std::time::Duration::from_millis(window_ms),
                    max_batch: 1024,
                    ..BatcherConfig::default()
                },
                drive: DriveParams::default(),
                ..CoordinatorConfig::default()
            },
            ds.tapes.iter().take(n_tapes).map(|t| t.tape.clone()),
            Arc::from(scheduler_by_name("SimpleDP").unwrap()),
        );
        let mut rng = Rng::new(3);
        for id in 0..n_reqs {
            let t = &ds.tapes[rng.below(n_tapes as u64) as usize];
            coord
                .submit(ReadRequest {
                    id,
                    tape: t.tape.name.clone(),
                    file_index: rng.zipf(t.tape.n_files() as u64, 1.2) as usize - 1,
                })
                .expect("bench requests are routable");
        }
        let (_, m) = coord.finish();
        println!(
            "{:>8}ms {:>9} {:>14.1} {:>14.2}",
            window_ms,
            m.batches,
            m.mean_service_s,
            t0.elapsed().as_secs_f64()
        );
    }

    // --- 3. U sweep: optimal structure vs penalty -------------------------
    let tape = ds
        .tapes
        .iter()
        .filter(|t| (30..=50).contains(&t.n_req()))
        .min_by_key(|t| t.n_req())
        .expect("small tape exists");
    println!(
        "\n=== U-turn penalty sweep on {} (n_req={}) ===",
        tape.tape.name,
        tape.n_req()
    );
    println!("{:>16} {:>10} {:>12}", "U (bytes)", "detours", "GS/OPT");
    let avg = ds.avg_segment_size();
    for u in [0, avg / 8, avg / 2, avg, 4 * avg] {
        let inst = tape.instance(u).unwrap();
        let sched = Dp.schedule(&inst);
        let opt = evaluate(&inst, &sched).cost;
        let gs = evaluate(&inst, &Gs.schedule(&inst)).cost;
        println!("{u:>16} {:>10} {:>12.4}", sched.len(), gs as f64 / opt as f64);
    }

    suite.write_csv("bench_ablations.csv");
}
