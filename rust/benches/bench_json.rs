//! Bench — machine-readable summary: one JSON document
//! (`BENCH_replay.json`) carrying the four load-bearing throughput
//! numbers of the stack, one per layer seam:
//!
//! - `dense_wavefront` — ns per uncached SimpleDP dense-table fill (the
//!   algorithmic kernel; deliberately `simpledp_dense::dense_cost_into`,
//!   NOT the runtime dense backend, whose per-thread memo cache would
//!   turn this into a cache-hit benchmark).
//! - `dense_incremental` — ns per solve over a grow-by-one-file request
//!   sequence served by the incremental re-solve table (each append
//!   extends the dense wavefront instead of refilling it; every step is
//!   asserted bit-equal to `dense_cost_into`).
//! - `replay_events` — virtual-replay completions per wall second (the
//!   measurement engine).
//! - `parallel_replay` — speedup (×) of the same open-loop sharded replay
//!   fanned out over 4 worker threads via `simulate_parallel`; the merged
//!   outcome is asserted identical to the single-threaded one.
//! - `coordinator_submits` — closed-loop submits per wall second into an
//!   in-process `Coordinator` (the serving seam as a function call).
//! - `loopback_rpc_submits` — the same closed loop through a
//!   loopback-networked coordinator/worker fleet (the serving seam as a
//!   framed TCP round trip; the ratio to the previous number is the RPC
//!   tax in throughput terms).
//! - `trace_overhead` — percent slowdown of the virtual replay when the
//!   request-lifecycle `TraceRecorder` is attached (the observability
//!   tax; near zero by design, since recording is nine ring-buffer
//!   writes per completion).
//! - `work_stealing` — factor by which the `--steal` epoch re-pack
//!   shrinks the busiest worker's load vs static round-robin on a
//!   deliberately skewed ring (one hot shard); the merged outcomes are
//!   asserted identical, so only the balance moves.
//! - `serving_incremental` — closed-loop submits per wall second into a
//!   `Coordinator` whose drive workers solve through the incremental
//!   re-solve backend; the run must record table appends (the serving
//!   path actually repaired tables instead of re-solving from scratch).
//! - `streaming_replay_events` / `streaming_parallel_speedup` /
//!   `streaming_peak_alloc_mb` — a generated on-disk trace replayed
//!   through `StreamingTraceArrivals` (10⁸ events full / 2×10⁵ smoke,
//!   override with `TAPESCHED_STREAM_EVENTS`): events per wall second
//!   single-threaded, the speedup of the same replay over worker
//!   threads, and the peak live allocation during the run measured by
//!   the counting-allocator shim below (the arrival side stays
//!   O(reorder window); what grows is the completion log).
//!
//! `make bench-json` runs this; `--smoke` (or `TAPESCHED_SMOKE=1`) keeps
//! it to seconds. Schema history: v4 added the `work_stealing`,
//! `serving_incremental`, and `streaming_*` cases.

use std::io::Write as _;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use tapesched::bench::{bench, smoke_requested, BenchConfig};
use tapesched::cluster::HashRing;
use tapesched::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig};
use tapesched::dataset::{generate_dataset, open_trace_file, GeneratorConfig};
use tapesched::model::Tape;
use tapesched::net::{CoordinatorServerConfig, LoopbackFleet};
use tapesched::obs::{Stage, TraceRecorder, DEFAULT_TRACE_CAP};
use tapesched::model::Instance;
use tapesched::replay::{
    drive_closed_loop, simulate, simulate_parallel, simulate_parallel_balanced, simulate_traced,
    ArrivalModel, AssignMode, LoopMode, PoissonArrivals, ReplayConfig, RequestMix,
    StreamingTraceArrivals, DEFAULT_TRACE_WINDOW,
};
use tapesched::runtime::{backend_by_name, IncrementalTable};
use tapesched::sched::simpledp_dense::{dense_cost_into, DenseScratch};
use tapesched::sched::{scheduler_by_name, Gs};
use tapesched::sim::{Affinity, DriveParams};
use tapesched::util::rng::Rng;

// ---------------------------------------------------------------------
// Allocation-counting shim: the flat-memory evidence for the streaming
// replay case — a pass-through to the system allocator plus three
// relaxed counters, no external deps. (The library crate forbids unsafe
// code; this bench binary is its own crate root, so the one `unsafe
// impl` the evidence needs lives here.) The default `realloc` /
// `alloc_zeroed` provided methods route through `alloc`/`dealloc`, so
// the counters see every byte.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static LIVE_BYTES: AtomicU64 = AtomicU64::new(0);
static PEAK_BYTES: AtomicU64 = AtomicU64::new(0);
static TOTAL_ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            let live = LIVE_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed)
                + layout.size() as u64;
            PEAK_BYTES.fetch_max(live, Ordering::Relaxed);
            TOTAL_ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, p: *mut u8, layout: Layout) {
        System.dealloc(p, layout);
        LIVE_BYTES.fetch_sub(layout.size() as u64, Ordering::Relaxed);
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Start a peak-allocation measurement window: returns the live-byte
/// baseline and resets the high-water mark to it.
fn mem_mark() -> u64 {
    let live = LIVE_BYTES.load(Ordering::Relaxed);
    PEAK_BYTES.store(live, Ordering::Relaxed);
    live
}

/// Peak live bytes above the `mem_mark` baseline.
fn mem_peak_since(baseline: u64) -> u64 {
    PEAK_BYTES.load(Ordering::Relaxed).saturating_sub(baseline)
}

struct Entry {
    name: &'static str,
    value: f64,
    unit: &'static str,
}

/// Generate a sorted on-disk trace (`timestamp_ns<TAB>tape<TAB>file_id`)
/// of `events` reads over `catalog`, ~10k requests per virtual second.
/// Streamed straight to disk through a buffered writer — the trace is
/// never held in memory, mirroring how the replay will read it back.
fn write_stream_trace(path: &Path, catalog: &[Tape], events: u64, seed: u64) {
    let file = std::fs::File::create(path).expect("create streaming trace file");
    let mut w = std::io::BufWriter::with_capacity(1 << 20, file);
    let mut rng = Rng::new(seed);
    let mut t_ns: u64 = 0;
    for _ in 0..events {
        t_ns += 20_000 + rng.next_u64() % 160_000;
        let tape = (rng.next_u64() % catalog.len() as u64) as usize;
        let file_id = (rng.next_u64() % catalog[tape].n_files() as u64) as usize;
        writeln!(w, "{t_ns}\t{}\t{file_id}", catalog[tape].name)
            .expect("write streaming trace line");
    }
    w.flush().expect("flush streaming trace");
}

/// A catalog whose ring placement is deliberately skewed: `hot_tapes`
/// tapes on one hot shard, one tape on each of two cold shards whose ids
/// collide with the hot worker under `shard % 2` — the geometry where
/// static round-robin piles everything on one worker.
fn skewed_catalog(n_shards: usize, vnodes: usize, hot_tapes: usize) -> Vec<Tape> {
    let ring = HashRing::new(n_shards, vnodes);
    let (hot, colds) = (0usize, [2usize, 4]);
    let mut tapes = Vec::new();
    let mut hot_found = 0usize;
    let mut cold_found = [false; 2];
    let mut i = 0usize;
    while hot_found < hot_tapes || cold_found.iter().any(|&c| !c) {
        let name = format!("SKEW{i:05}");
        let s = ring.route(&name);
        if s == hot && hot_found < hot_tapes {
            tapes.push(Tape::from_sizes(name, &[1_000; 40]));
            hot_found += 1;
        } else if let Some(k) = colds.iter().position(|&c| c == s) {
            if !cold_found[k] {
                tapes.push(Tape::from_sizes(name, &[1_000; 40]));
                cold_found[k] = true;
            }
        }
        i += 1;
        assert!(i < 200_000, "ring never routed a candidate to the target shards");
    }
    tapes
}

/// One giant batching window flushed at drain: submit throughput then
/// measures the submit/batcher path itself, and because the in-process
/// and loopback runs share this config, their ratio isolates the wire.
fn drain_flush_cfg(n_drives: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        n_drives,
        batcher: BatcherConfig {
            window: Duration::from_secs(3_600),
            ..BatcherConfig::default()
        },
        drive: DriveParams::default(),
        affinity: Affinity::None,
        exclusive_tapes: false,
    }
}

fn main() {
    let smoke = smoke_requested();
    let mut entries: Vec<Entry> = Vec::new();

    let ds = if smoke {
        generate_dataset(&GeneratorConfig {
            n_tapes: 8,
            nf: (40, 60.0, 70.0, 150),
            nreq: (10, 25.0, 30.0, 60),
            n: (20, 60.0, 70.0, 180),
            ..Default::default()
        })
    } else {
        generate_dataset(&GeneratorConfig { n_tapes: 16, ..Default::default() })
    };
    let catalog: Vec<Tape> = ds.tapes.iter().map(|t| t.tape.clone()).collect();

    // 1. The algorithmic kernel: one dense SimpleDP wavefront fill.
    {
        let u = ds.avg_segment_size();
        let inst = ds.tapes[0].instance(u).expect("generated tape must yield an instance");
        let mut scratch = DenseScratch::default();
        let cfg = if smoke { BenchConfig::smoke() } else { BenchConfig::quick() };
        let r = bench("dense_wavefront", &cfg, || dense_cost_into(&inst, &mut scratch));
        let ns = r.median * 1e9;
        println!("    → dense_wavefront: {ns:.0} ns/op ({} iters)", r.iters);
        entries.push(Entry { name: "dense_wavefront", value: ns, unit: "ns/op" });
    }

    // 1b. The incremental re-solve table: a request set growing by one
    // file per step, each append extending the dense wavefront in place.
    {
        let u = ds.avg_segment_size();
        let td = ds
            .tapes
            .iter()
            .max_by_key(|t| t.n_req())
            .expect("generated dataset is non-empty");
        let steps: Vec<Instance> = (1..=td.n_req())
            .map(|k| {
                Instance::from_tape(&td.tape, &td.requests[..k], u)
                    .expect("request prefix must yield an instance")
            })
            .collect();
        // Correctness before timing: every grow step bit-equal to the
        // dense kernel.
        let mut table = IncrementalTable::new();
        let mut scratch = DenseScratch::default();
        for inst in &steps {
            let (cost, _) = table.opt_cost(inst);
            assert_eq!(
                cost,
                dense_cost_into(inst, &mut scratch),
                "incremental re-solve diverged from the dense kernel"
            );
        }
        let rounds = if smoke { 20 } else { 200 };
        let wall = Instant::now();
        for _ in 0..rounds {
            let mut table = IncrementalTable::new();
            for inst in &steps {
                std::hint::black_box(table.opt_cost(inst).0);
            }
        }
        let ns = wall.elapsed().as_secs_f64() * 1e9 / (rounds * steps.len()) as f64;
        println!(
            "    → dense_incremental: {ns:.0} ns/op ({} grow steps × {rounds} rounds)",
            steps.len()
        );
        entries.push(Entry { name: "dense_incremental", value: ns, unit: "ns/op" });
    }

    // 2. The measurement engine: virtual replay, completions per wall s.
    {
        let cfg = ReplayConfig {
            n_drives: 8,
            batcher: BatcherConfig {
                window: Duration::from_millis(100),
                max_batch: 256,
                ..BatcherConfig::default()
            },
            drive: DriveParams::default(),
            mode: LoopMode::Open,
            retry_backoff_s: 0.01,
            ..ReplayConfig::default()
        };
        let (rate, duration) = if smoke { (50.0, 2.0) } else { (100.0, 60.0) };
        let policy = scheduler_by_name("SimpleDP").unwrap();
        let mut model =
            PoissonArrivals::new(RequestMix::new(&catalog), rate, duration, 7);
        let wall = Instant::now();
        let out = simulate(&cfg, &catalog, policy.as_ref(), &mut model);
        let s = wall.elapsed().as_secs_f64().max(1e-9);
        assert!(out.stats.completed > 0, "replay must serve requests");
        let eps = out.stats.completed as f64 / s;
        println!(
            "    → replay_events: {:.0} events/s ({} completions in {s:.3} wall s)",
            eps, out.stats.completed
        );
        entries.push(Entry { name: "replay_events", value: eps, unit: "events/s" });

        // 2b. The observability tax: the identical replay with the span
        // recorder attached. The recorder is a pure observer, so the
        // outcome must match and the slowdown should be noise-level.
        let rec = TraceRecorder::new(DEFAULT_TRACE_CAP);
        let mut model = PoissonArrivals::new(RequestMix::new(&catalog), rate, duration, 7);
        let wall = Instant::now();
        let traced = simulate_traced(&cfg, &catalog, policy.as_ref(), &mut model, Some(&rec));
        let s_traced = wall.elapsed().as_secs_f64().max(1e-9);
        assert_eq!(traced.stats.completed, out.stats.completed, "tracing perturbed the replay");
        assert_eq!(rec.len() as u64, Stage::CHAIN.len() as u64 * traced.stats.completed);
        let eps_traced = traced.stats.completed as f64 / s_traced;
        let overhead_pct = (eps / eps_traced - 1.0) * 100.0;
        println!(
            "    → trace_overhead: {overhead_pct:.2} % ({eps_traced:.0} traced vs {eps:.0} plain events/s)"
        );
        entries.push(Entry { name: "trace_overhead", value: overhead_pct, unit: "percent" });
    }

    // 2c. Parallel sharded replay: the same open-loop replay fanned out
    // over 4 worker threads and merged back. The merge contract is
    // byte-identity, so the outcome comparison is an assert, not a
    // statistic; the entry's value is the wall-clock speedup.
    {
        let cfg = ReplayConfig {
            n_drives: 4,
            batcher: BatcherConfig {
                window: Duration::from_millis(100),
                max_batch: 256,
                ..BatcherConfig::default()
            },
            drive: DriveParams::default(),
            mode: LoopMode::Open,
            retry_backoff_s: 0.01,
            n_shards: 8,
            vnodes: 64,
            ..ReplayConfig::default()
        };
        let (rate, duration) = if smoke { (80.0, 2.0) } else { (150.0, 60.0) };
        let policy = scheduler_by_name("SimpleDP").unwrap();
        let make_model = || -> Box<dyn ArrivalModel> {
            Box::new(PoissonArrivals::new(RequestMix::new(&catalog), rate, duration, 11))
        };
        let wall = Instant::now();
        let single = {
            let mut model = make_model();
            simulate(&cfg, &catalog, policy.as_ref(), model.as_mut())
        };
        let s_single = wall.elapsed().as_secs_f64().max(1e-9);
        let wall = Instant::now();
        let parallel = simulate_parallel(&cfg, &catalog, policy.as_ref(), &make_model, 4);
        let s_parallel = wall.elapsed().as_secs_f64().max(1e-9);
        assert!(single.stats.completed > 0, "parallel bench replay must serve requests");
        assert_eq!(parallel.stats.submitted, single.stats.submitted);
        assert_eq!(parallel.stats.completed, single.stats.completed);
        assert_eq!(parallel.stats.makespan_us, single.stats.makespan_us);
        assert_eq!(
            parallel.completions, single.completions,
            "parallel merge diverged from the single-threaded replay"
        );
        let speedup = s_single / s_parallel;
        println!(
            "    → parallel_replay: {speedup:.2} x \
             (1 thread {s_single:.3} s vs 4 threads {s_parallel:.3} s)"
        );
        entries.push(Entry { name: "parallel_replay", value: speedup, unit: "x" });
    }

    // 2d. Work stealing on a skewed ring: one hot shard owns nearly all
    // tapes, so static round-robin piles hot + cold shards onto worker 0
    // and idles worker 1. The `--steal` epoch re-pack must recover that
    // idle time; the entry's value is the factor by which it shrinks the
    // busiest worker's virtual load. Byte-identity across modes is an
    // assert, not a statistic.
    {
        let cfg = ReplayConfig {
            n_drives: 3,
            batcher: BatcherConfig {
                window: Duration::from_millis(100),
                max_batch: 256,
                ..BatcherConfig::default()
            },
            drive: DriveParams::default(),
            mode: LoopMode::Open,
            retry_backoff_s: 0.01,
            n_shards: 9,
            vnodes: 64,
            ..ReplayConfig::default()
        };
        let skewed = skewed_catalog(cfg.n_shards, cfg.vnodes, 18);
        let (rate, duration) = if smoke { (60.0, 2.0) } else { (100.0, 30.0) };
        let make_model = || -> Box<dyn ArrivalModel> {
            Box::new(PoissonArrivals::new(RequestMix::new(&skewed), rate, duration, 13))
        };
        let run = |mode| simulate_parallel_balanced(&cfg, &skewed, &Gs, &make_model, 2, mode);
        let (out_rr, rr) = run(AssignMode::RoundRobin);
        let (out_stolen, stolen) = run(AssignMode::Stolen);
        assert_eq!(
            out_rr.completions, out_stolen.completions,
            "assignment mode perturbed the replay"
        );
        assert!(stolen.steal_events > 0, "skewed ring must trigger steals");
        let max_rr = rr.worker_busy_us.iter().copied().max().unwrap_or(0);
        let max_stolen = stolen.worker_busy_us.iter().copied().max().unwrap_or(1);
        let factor = max_rr as f64 / max_stolen.max(1) as f64;
        println!(
            "    → work_stealing: {factor:.2} x busiest-worker load reduction \
             ({} steals; busy ratio {:.2} vs round-robin {})",
            stolen.steal_events,
            stolen.busy_ratio(),
            if rr.busy_ratio().is_finite() { format!("{:.2}", rr.busy_ratio()) } else { "inf".into() },
        );
        entries.push(Entry { name: "work_stealing", value: factor, unit: "x" });
    }

    // 2e. The flat-memory streaming replay: a generated on-disk trace
    // pushed through `StreamingTraceArrivals` (never materialized), once
    // single-threaded and once fanned out. 10⁸ events in the full run,
    // 2×10⁵ in smoke; `TAPESCHED_STREAM_EVENTS` overrides either.
    {
        let events: u64 = std::env::var("TAPESCHED_STREAM_EVENTS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(if smoke { 200_000 } else { 100_000_000 });
        let cfg = ReplayConfig {
            n_drives: 4,
            batcher: BatcherConfig {
                window: Duration::from_millis(100),
                max_batch: 256,
                ..BatcherConfig::default()
            },
            drive: DriveParams::default(),
            mode: LoopMode::Open,
            retry_backoff_s: 0.01,
            n_shards: 8,
            vnodes: 64,
            ..ReplayConfig::default()
        };
        let trace_path = Path::new("BENCH_stream_trace.tsv");
        write_stream_trace(trace_path, &catalog, events, 17);
        let make_model = || -> Box<dyn ArrivalModel> {
            let reader = open_trace_file(trace_path).expect("streaming trace written above");
            Box::new(StreamingTraceArrivals::new(
                "stream",
                reader,
                &catalog,
                DEFAULT_TRACE_WINDOW,
            ))
        };
        let baseline = mem_mark();
        let wall = Instant::now();
        let single = {
            let mut model = make_model();
            simulate(&cfg, &catalog, &Gs, model.as_mut())
        };
        let s_single = wall.elapsed().as_secs_f64().max(1e-9);
        let peak = mem_peak_since(baseline);
        assert_eq!(
            single.stats.submitted + single.stats.shed,
            events,
            "every trace event must be submitted or shed"
        );
        let threads = 4;
        let wall = Instant::now();
        let parallel = simulate_parallel(&cfg, &catalog, &Gs, &make_model, threads);
        let s_parallel = wall.elapsed().as_secs_f64().max(1e-9);
        assert_eq!(parallel.stats.submitted, single.stats.submitted);
        assert_eq!(parallel.stats.completed, single.stats.completed);
        assert_eq!(
            parallel.completions, single.completions,
            "streaming parallel merge diverged from the single-threaded replay"
        );
        std::fs::remove_file(trace_path).ok();
        let eps = events as f64 / s_single;
        let speedup = s_single / s_parallel;
        let peak_mb = peak as f64 / (1024.0 * 1024.0);
        println!(
            "    → streaming_replay_events: {eps:.0} events/s \
             ({events} events in {s_single:.3} wall s)"
        );
        println!(
            "    → streaming_parallel_speedup: {speedup:.2} x \
             (1 thread {s_single:.3} s vs {threads} threads {s_parallel:.3} s)"
        );
        println!(
            "    → streaming_peak_alloc_mb: {peak_mb:.1} MB peak live allocation \
             ({} allocations; arrivals stay O(window), the completion log grows)",
            TOTAL_ALLOCS.load(Ordering::Relaxed)
        );
        entries.push(Entry { name: "streaming_replay_events", value: eps, unit: "events/s" });
        entries.push(Entry { name: "streaming_parallel_speedup", value: speedup, unit: "x" });
        entries.push(Entry { name: "streaming_peak_alloc_mb", value: peak_mb, unit: "MB" });
    }

    // 3 + 4. The serving seam, in-process vs over the wire. Same config,
    // same request count, same closed loop; the driver polls in-flight
    // before every submit, so the loopback number pays two framed round
    // trips per request (MetricsPull + Submit) — that is the seam's
    // honest per-request cost, not an artifact.
    let n_requests: u64 = if smoke { 200 } else { 5_000 };
    {
        let coord = Coordinator::start(drain_flush_cfg(4), catalog.clone(), Arc::new(Gs));
        let mut model =
            PoissonArrivals::new(RequestMix::new(&catalog), 1_000.0, f64::INFINITY, 7);
        let wall = Instant::now();
        let stats = drive_closed_loop(
            &coord,
            &catalog,
            &mut model,
            n_requests,
            Duration::from_millis(1),
            n_requests,
        );
        let s = wall.elapsed().as_secs_f64().max(1e-9);
        assert_eq!(stats.submitted, n_requests);
        let (_completions, m) = coord.finish();
        assert_eq!(m.completed + m.shed, n_requests);
        let sps = n_requests as f64 / s;
        println!("    → coordinator_submits: {sps:.0} submits/s ({n_requests} in {s:.3} wall s)");
        entries.push(Entry { name: "coordinator_submits", value: sps, unit: "submits/s" });
    }
    {
        let fleet = LoopbackFleet::spawn(
            CoordinatorServerConfig {
                n_shards: 1,
                vnodes: 64,
                shard: drain_flush_cfg(4),
                policy: "GS".to_string(),
                kill: None,
                push_ms: 0,
                metrics_listen: None,
            },
            catalog.clone(),
        )
        .expect("spawn loopback fleet");
        let client = fleet.client().expect("connect loopback client");
        let mut model =
            PoissonArrivals::new(RequestMix::new(&catalog), 1_000.0, f64::INFINITY, 7);
        let wall = Instant::now();
        let stats = drive_closed_loop(
            &client,
            &catalog,
            &mut model,
            n_requests,
            Duration::from_millis(1),
            n_requests,
        );
        let s = wall.elapsed().as_secs_f64().max(1e-9);
        assert_eq!(stats.submitted, n_requests);
        let (_completions, m) = client.drain().expect("drain loopback fleet");
        assert_eq!(m.completed + m.shed, n_requests);
        let _ = fleet.join();
        let sps = n_requests as f64 / s;
        println!("    → loopback_rpc_submits: {sps:.0} submits/s ({n_requests} in {s:.3} wall s)");
        entries.push(Entry { name: "loopback_rpc_submits", value: sps, unit: "submits/s" });
    }

    // 5. The serving path through the incremental backend: same closed
    // loop as `coordinator_submits`, but drive workers solve via the
    // per-tape re-solve tables. The snapshot must show appended columns
    // (growing backlogs repaired in place, not re-solved from scratch)
    // with the drain invariant intact.
    {
        let backend = backend_by_name("incremental").expect("incremental backend is built in");
        let coord =
            Coordinator::start_with_backend(drain_flush_cfg(4), catalog.clone(), backend);
        let mut model =
            PoissonArrivals::new(RequestMix::new(&catalog), 1_000.0, f64::INFINITY, 7);
        let wall = Instant::now();
        let stats = drive_closed_loop(
            &coord,
            &catalog,
            &mut model,
            n_requests,
            Duration::from_millis(1),
            n_requests,
        );
        let s = wall.elapsed().as_secs_f64().max(1e-9);
        assert_eq!(stats.submitted, n_requests);
        let (_completions, m) = coord.finish();
        assert_eq!(m.completed + m.shed, n_requests, "drain invariant broken");
        assert!(
            m.incremental_appends > 0,
            "serving through the incremental backend must append table columns"
        );
        let sps = n_requests as f64 / s;
        println!(
            "    → serving_incremental: {sps:.0} submits/s \
             ({} appends / {} rebuilds over {n_requests} requests)",
            m.incremental_appends, m.incremental_rebuilds
        );
        entries.push(Entry { name: "serving_incremental", value: sps, unit: "submits/s" });
    }

    let body: Vec<String> = entries
        .iter()
        .map(|e| {
            format!(
                "    {{\"name\": \"{}\", \"value\": {:.6}, \"unit\": \"{}\"}}",
                e.name, e.value, e.unit
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"schema\": \"tapesched-bench-v4\",\n  \"smoke\": {smoke},\n  \
         \"benches\": [\n{}\n  ]\n}}\n",
        body.join(",\n")
    );
    std::fs::write("BENCH_replay.json", &json).expect("write BENCH_replay.json");
    println!("wrote BENCH_replay.json ({} benches)", entries.len());
}
