//! Bench — machine-readable summary: one JSON document
//! (`BENCH_replay.json`) carrying the four load-bearing throughput
//! numbers of the stack, one per layer seam:
//!
//! - `dense_wavefront` — ns per uncached SimpleDP dense-table fill (the
//!   algorithmic kernel; deliberately `simpledp_dense::dense_cost_into`,
//!   NOT the runtime dense backend, whose per-thread memo cache would
//!   turn this into a cache-hit benchmark).
//! - `dense_incremental` — ns per solve over a grow-by-one-file request
//!   sequence served by the incremental re-solve table (each append
//!   extends the dense wavefront instead of refilling it; every step is
//!   asserted bit-equal to `dense_cost_into`).
//! - `replay_events` — virtual-replay completions per wall second (the
//!   measurement engine).
//! - `parallel_replay` — speedup (×) of the same open-loop sharded replay
//!   fanned out over 4 worker threads via `simulate_parallel`; the merged
//!   outcome is asserted identical to the single-threaded one.
//! - `coordinator_submits` — closed-loop submits per wall second into an
//!   in-process `Coordinator` (the serving seam as a function call).
//! - `loopback_rpc_submits` — the same closed loop through a
//!   loopback-networked coordinator/worker fleet (the serving seam as a
//!   framed TCP round trip; the ratio to the previous number is the RPC
//!   tax in throughput terms).
//! - `trace_overhead` — percent slowdown of the virtual replay when the
//!   request-lifecycle `TraceRecorder` is attached (the observability
//!   tax; near zero by design, since recording is nine ring-buffer
//!   writes per completion).
//!
//! `make bench-json` runs this; `--smoke` (or `TAPESCHED_SMOKE=1`) keeps
//! it to seconds.

use std::sync::Arc;
use std::time::{Duration, Instant};

use tapesched::bench::{bench, smoke_requested, BenchConfig};
use tapesched::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig};
use tapesched::dataset::{generate_dataset, GeneratorConfig};
use tapesched::model::Tape;
use tapesched::net::{CoordinatorServerConfig, LoopbackFleet};
use tapesched::obs::{Stage, TraceRecorder, DEFAULT_TRACE_CAP};
use tapesched::model::Instance;
use tapesched::replay::{
    drive_closed_loop, simulate, simulate_parallel, simulate_traced, ArrivalModel, LoopMode,
    PoissonArrivals, ReplayConfig, RequestMix,
};
use tapesched::runtime::IncrementalTable;
use tapesched::sched::simpledp_dense::{dense_cost_into, DenseScratch};
use tapesched::sched::{scheduler_by_name, Gs};
use tapesched::sim::{Affinity, DriveParams};

struct Entry {
    name: &'static str,
    value: f64,
    unit: &'static str,
}

/// One giant batching window flushed at drain: submit throughput then
/// measures the submit/batcher path itself, and because the in-process
/// and loopback runs share this config, their ratio isolates the wire.
fn drain_flush_cfg(n_drives: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        n_drives,
        batcher: BatcherConfig {
            window: Duration::from_secs(3_600),
            ..BatcherConfig::default()
        },
        drive: DriveParams::default(),
        affinity: Affinity::None,
        exclusive_tapes: false,
    }
}

fn main() {
    let smoke = smoke_requested();
    let mut entries: Vec<Entry> = Vec::new();

    let ds = if smoke {
        generate_dataset(&GeneratorConfig {
            n_tapes: 8,
            nf: (40, 60.0, 70.0, 150),
            nreq: (10, 25.0, 30.0, 60),
            n: (20, 60.0, 70.0, 180),
            ..Default::default()
        })
    } else {
        generate_dataset(&GeneratorConfig { n_tapes: 16, ..Default::default() })
    };
    let catalog: Vec<Tape> = ds.tapes.iter().map(|t| t.tape.clone()).collect();

    // 1. The algorithmic kernel: one dense SimpleDP wavefront fill.
    {
        let u = ds.avg_segment_size();
        let inst = ds.tapes[0].instance(u).expect("generated tape must yield an instance");
        let mut scratch = DenseScratch::default();
        let cfg = if smoke { BenchConfig::smoke() } else { BenchConfig::quick() };
        let r = bench("dense_wavefront", &cfg, || dense_cost_into(&inst, &mut scratch));
        let ns = r.median * 1e9;
        println!("    → dense_wavefront: {ns:.0} ns/op ({} iters)", r.iters);
        entries.push(Entry { name: "dense_wavefront", value: ns, unit: "ns/op" });
    }

    // 1b. The incremental re-solve table: a request set growing by one
    // file per step, each append extending the dense wavefront in place.
    {
        let u = ds.avg_segment_size();
        let td = ds
            .tapes
            .iter()
            .max_by_key(|t| t.n_req())
            .expect("generated dataset is non-empty");
        let steps: Vec<Instance> = (1..=td.n_req())
            .map(|k| {
                Instance::from_tape(&td.tape, &td.requests[..k], u)
                    .expect("request prefix must yield an instance")
            })
            .collect();
        // Correctness before timing: every grow step bit-equal to the
        // dense kernel.
        let mut table = IncrementalTable::new();
        let mut scratch = DenseScratch::default();
        for inst in &steps {
            let (cost, _) = table.opt_cost(inst);
            assert_eq!(
                cost,
                dense_cost_into(inst, &mut scratch),
                "incremental re-solve diverged from the dense kernel"
            );
        }
        let rounds = if smoke { 20 } else { 200 };
        let wall = Instant::now();
        for _ in 0..rounds {
            let mut table = IncrementalTable::new();
            for inst in &steps {
                std::hint::black_box(table.opt_cost(inst).0);
            }
        }
        let ns = wall.elapsed().as_secs_f64() * 1e9 / (rounds * steps.len()) as f64;
        println!(
            "    → dense_incremental: {ns:.0} ns/op ({} grow steps × {rounds} rounds)",
            steps.len()
        );
        entries.push(Entry { name: "dense_incremental", value: ns, unit: "ns/op" });
    }

    // 2. The measurement engine: virtual replay, completions per wall s.
    {
        let cfg = ReplayConfig {
            n_drives: 8,
            batcher: BatcherConfig {
                window: Duration::from_millis(100),
                max_batch: 256,
                ..BatcherConfig::default()
            },
            drive: DriveParams::default(),
            mode: LoopMode::Open,
            retry_backoff_s: 0.01,
            ..ReplayConfig::default()
        };
        let (rate, duration) = if smoke { (50.0, 2.0) } else { (100.0, 60.0) };
        let policy = scheduler_by_name("SimpleDP").unwrap();
        let mut model =
            PoissonArrivals::new(RequestMix::new(&catalog), rate, duration, 7);
        let wall = Instant::now();
        let out = simulate(&cfg, &catalog, policy.as_ref(), &mut model);
        let s = wall.elapsed().as_secs_f64().max(1e-9);
        assert!(out.stats.completed > 0, "replay must serve requests");
        let eps = out.stats.completed as f64 / s;
        println!(
            "    → replay_events: {:.0} events/s ({} completions in {s:.3} wall s)",
            eps, out.stats.completed
        );
        entries.push(Entry { name: "replay_events", value: eps, unit: "events/s" });

        // 2b. The observability tax: the identical replay with the span
        // recorder attached. The recorder is a pure observer, so the
        // outcome must match and the slowdown should be noise-level.
        let rec = TraceRecorder::new(DEFAULT_TRACE_CAP);
        let mut model = PoissonArrivals::new(RequestMix::new(&catalog), rate, duration, 7);
        let wall = Instant::now();
        let traced = simulate_traced(&cfg, &catalog, policy.as_ref(), &mut model, Some(&rec));
        let s_traced = wall.elapsed().as_secs_f64().max(1e-9);
        assert_eq!(traced.stats.completed, out.stats.completed, "tracing perturbed the replay");
        assert_eq!(rec.len() as u64, Stage::CHAIN.len() as u64 * traced.stats.completed);
        let eps_traced = traced.stats.completed as f64 / s_traced;
        let overhead_pct = (eps / eps_traced - 1.0) * 100.0;
        println!(
            "    → trace_overhead: {overhead_pct:.2} % ({eps_traced:.0} traced vs {eps:.0} plain events/s)"
        );
        entries.push(Entry { name: "trace_overhead", value: overhead_pct, unit: "percent" });
    }

    // 2c. Parallel sharded replay: the same open-loop replay fanned out
    // over 4 worker threads and merged back. The merge contract is
    // byte-identity, so the outcome comparison is an assert, not a
    // statistic; the entry's value is the wall-clock speedup.
    {
        let cfg = ReplayConfig {
            n_drives: 4,
            batcher: BatcherConfig {
                window: Duration::from_millis(100),
                max_batch: 256,
                ..BatcherConfig::default()
            },
            drive: DriveParams::default(),
            mode: LoopMode::Open,
            retry_backoff_s: 0.01,
            n_shards: 8,
            vnodes: 64,
            ..ReplayConfig::default()
        };
        let (rate, duration) = if smoke { (80.0, 2.0) } else { (150.0, 60.0) };
        let policy = scheduler_by_name("SimpleDP").unwrap();
        let make_model = || -> Box<dyn ArrivalModel> {
            Box::new(PoissonArrivals::new(RequestMix::new(&catalog), rate, duration, 11))
        };
        let wall = Instant::now();
        let single = {
            let mut model = make_model();
            simulate(&cfg, &catalog, policy.as_ref(), model.as_mut())
        };
        let s_single = wall.elapsed().as_secs_f64().max(1e-9);
        let wall = Instant::now();
        let parallel = simulate_parallel(&cfg, &catalog, policy.as_ref(), &make_model, 4);
        let s_parallel = wall.elapsed().as_secs_f64().max(1e-9);
        assert!(single.stats.completed > 0, "parallel bench replay must serve requests");
        assert_eq!(parallel.stats.submitted, single.stats.submitted);
        assert_eq!(parallel.stats.completed, single.stats.completed);
        assert_eq!(parallel.stats.makespan_us, single.stats.makespan_us);
        assert_eq!(
            parallel.completions, single.completions,
            "parallel merge diverged from the single-threaded replay"
        );
        let speedup = s_single / s_parallel;
        println!(
            "    → parallel_replay: {speedup:.2} x \
             (1 thread {s_single:.3} s vs 4 threads {s_parallel:.3} s)"
        );
        entries.push(Entry { name: "parallel_replay", value: speedup, unit: "x" });
    }

    // 3 + 4. The serving seam, in-process vs over the wire. Same config,
    // same request count, same closed loop; the driver polls in-flight
    // before every submit, so the loopback number pays two framed round
    // trips per request (MetricsPull + Submit) — that is the seam's
    // honest per-request cost, not an artifact.
    let n_requests: u64 = if smoke { 200 } else { 5_000 };
    {
        let coord = Coordinator::start(drain_flush_cfg(4), catalog.clone(), Arc::new(Gs));
        let mut model =
            PoissonArrivals::new(RequestMix::new(&catalog), 1_000.0, f64::INFINITY, 7);
        let wall = Instant::now();
        let stats = drive_closed_loop(
            &coord,
            &catalog,
            &mut model,
            n_requests,
            Duration::from_millis(1),
            n_requests,
        );
        let s = wall.elapsed().as_secs_f64().max(1e-9);
        assert_eq!(stats.submitted, n_requests);
        let (_completions, m) = coord.finish();
        assert_eq!(m.completed + m.shed, n_requests);
        let sps = n_requests as f64 / s;
        println!("    → coordinator_submits: {sps:.0} submits/s ({n_requests} in {s:.3} wall s)");
        entries.push(Entry { name: "coordinator_submits", value: sps, unit: "submits/s" });
    }
    {
        let fleet = LoopbackFleet::spawn(
            CoordinatorServerConfig {
                n_shards: 1,
                vnodes: 64,
                shard: drain_flush_cfg(4),
                policy: "GS".to_string(),
                kill: None,
                push_ms: 0,
                metrics_listen: None,
            },
            catalog.clone(),
        )
        .expect("spawn loopback fleet");
        let client = fleet.client().expect("connect loopback client");
        let mut model =
            PoissonArrivals::new(RequestMix::new(&catalog), 1_000.0, f64::INFINITY, 7);
        let wall = Instant::now();
        let stats = drive_closed_loop(
            &client,
            &catalog,
            &mut model,
            n_requests,
            Duration::from_millis(1),
            n_requests,
        );
        let s = wall.elapsed().as_secs_f64().max(1e-9);
        assert_eq!(stats.submitted, n_requests);
        let (_completions, m) = client.drain().expect("drain loopback fleet");
        assert_eq!(m.completed + m.shed, n_requests);
        let _ = fleet.join();
        let sps = n_requests as f64 / s;
        println!("    → loopback_rpc_submits: {sps:.0} submits/s ({n_requests} in {s:.3} wall s)");
        entries.push(Entry { name: "loopback_rpc_submits", value: sps, unit: "submits/s" });
    }

    let body: Vec<String> = entries
        .iter()
        .map(|e| {
            format!(
                "    {{\"name\": \"{}\", \"value\": {:.6}, \"unit\": \"{}\"}}",
                e.name, e.value, e.unit
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"schema\": \"tapesched-bench-v3\",\n  \"smoke\": {smoke},\n  \
         \"benches\": [\n{}\n  ]\n}}\n",
        body.join(",\n")
    );
    std::fs::write("BENCH_replay.json", &json).expect("write BENCH_replay.json");
    println!("wrote BENCH_replay.json ({} benches)", entries.len());
}
