//! Bench E4 — the §5.3 "time to solution" table: median running time of
//! every algorithm over dataset instances, bucketed by instance size.
//!
//! The paper reports (single-thread Python): DP ≈ 281 s, LogDP(5) ≈ 47 s,
//! SimpleDP ≈ 21 s, LogDP(1) ≈ 5 s, NFGS ≈ 0.4 s, LogNFGS ≈ 0.1 s,
//! others < 1 ms. The *ordering* is the reproduction target; the Rust
//! implementations shift absolute numbers by the language factor.

use tapesched::bench::{bench, smoke_requested, BenchConfig, Suite};
use tapesched::dataset::{generate_dataset, GeneratorConfig};
use tapesched::sched::paper_schedulers;

fn main() {
    let smoke = smoke_requested();
    let ds = if smoke {
        // Small marginals: only the small bucket is populated, every
        // algorithm (exact DP included) finishes in seconds.
        generate_dataset(&GeneratorConfig {
            n_tapes: 8,
            nf: (40, 60.0, 70.0, 120),
            nreq: (10, 25.0, 30.0, 50),
            n: (20, 60.0, 70.0, 150),
            ..Default::default()
        })
    } else {
        generate_dataset(&GeneratorConfig::default())
    };
    let [_, _, u_avg] = ds.paper_u_values();

    // Size buckets over n_req: small / median-ish / large. The paper's
    // median instance has n_req ≈ 148.
    let buckets: [(&str, usize, usize); 3] =
        [("small(k<=60)", 2, 60), ("median(k<=180)", 61, 180), ("large(k<=300)", 181, 300)];

    let mut suite = Suite::new();
    println!("=== §5.3 timing table (median per instance; per size bucket) ===\n");
    for (label, lo, hi) in buckets {
        // Representative instance: the first tape whose n_req is closest
        // to the bucket midpoint.
        let mid = (lo + hi) / 2;
        let tape = ds
            .tapes
            .iter()
            .filter(|t| (lo..=hi).contains(&t.n_req()))
            .min_by_key(|t| t.n_req().abs_diff(mid));
        let Some(tape) = tape else { continue };
        let inst = tape.instance(u_avg).unwrap();
        println!("--- bucket {label}: tape {} (n_req = {}, n = {}) ---", tape.tape.name, inst.k(), inst.n());
        for algo in paper_schedulers() {
            // Exact DP on large instances is minutes; measure once there.
            let cfg = if smoke {
                BenchConfig::smoke()
            } else if algo.name() == "DP" && inst.k() > 150 {
                BenchConfig {
                    warmup: std::time::Duration::ZERO,
                    measure: std::time::Duration::ZERO,
                    max_iters: 1,
                    min_iters: 1,
                }
            } else if inst.k() > 60 {
                BenchConfig::quick()
            } else {
                BenchConfig::default()
            };
            let name = format!("{}/{}", algo.name(), label);
            let r = bench(&name, &cfg, || algo.schedule(&inst));
            suite.record(r);
        }
        println!();
    }
    suite.write_csv("bench_algo_runtimes.csv");
    println!("CSV → bench_algo_runtimes.csv");
}
