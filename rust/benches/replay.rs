//! Bench — the replay engine: virtual-time acceleration (virtual seconds
//! simulated per wall second) and end-to-end replay throughput per policy
//! and arrival model.

use std::time::Instant;

use tapesched::bench::{smoke_requested, BenchResult, Suite};
use tapesched::coordinator::BatcherConfig;
use tapesched::dataset::{generate_dataset, GeneratorConfig};
use tapesched::model::Tape;
use tapesched::replay::{
    simulate, ArrivalModel, BurstyArrivals, LoopMode, PoissonArrivals, ReplayConfig,
    RequestMix,
};
use tapesched::sched::scheduler_by_name;
use tapesched::sim::{Affinity, DriveParams};

fn main() {
    let smoke = smoke_requested();
    let mut suite = Suite::new();

    let ds = if smoke {
        generate_dataset(&GeneratorConfig {
            n_tapes: 8,
            nf: (40, 60.0, 70.0, 150),
            nreq: (10, 25.0, 30.0, 60),
            n: (20, 60.0, 70.0, 180),
            ..Default::default()
        })
    } else {
        generate_dataset(&GeneratorConfig { n_tapes: 32, ..Default::default() })
    };
    let catalog: Vec<Tape> = ds.tapes.iter().map(|t| t.tape.clone()).collect();
    let mix = RequestMix::new(&catalog);

    let cfg = ReplayConfig {
        n_drives: 8,
        batcher: BatcherConfig {
            window: std::time::Duration::from_millis(100),
            max_batch: 256,
            ..BatcherConfig::default()
        },
        drive: DriveParams::default(),
        mode: LoopMode::Open,
        retry_backoff_s: 0.01,
        ..ReplayConfig::default()
    };

    let (rate, duration) = if smoke { (50.0, 2.0) } else { (100.0, 120.0) };
    let policies: &[&str] = if smoke { &["SimpleDP"] } else { &["GS", "SimpleDP", "LogDP(1)"] };
    let arrivals: &[&str] = if smoke { &["poisson"] } else { &["poisson", "bursty"] };

    // Sharded replay: the same offered load over 1 vs 4 libraries (drive
    // pool scaled down so the fleet keeps 8 drives total) — measures the
    // routing layer's overhead and the per-shard batching win.
    for n_shards in [1usize, 4] {
        let shard_cfg = ReplayConfig {
            n_drives: 8 / n_shards,
            n_shards,
            ..cfg.clone()
        };
        let policy = scheduler_by_name("SimpleDP").unwrap();
        let mut model = PoissonArrivals::new(mix.clone(), rate, duration, 7);
        let wall = Instant::now();
        let out = simulate(&shard_cfg, &catalog, policy.as_ref(), &mut model);
        let s = wall.elapsed().as_secs_f64();
        assert!(out.stats.completed > 0, "sharded replay must serve requests");
        assert_eq!(out.per_shard.len(), n_shards);
        suite.record(BenchResult {
            name: format!("replay/sharded_{n_shards}x{}drives/SimpleDP", 8 / n_shards),
            iters: 1,
            median: s,
            mean: s,
            p10: s,
            p90: s,
        });
        println!(
            "    → shards={n_shards}: {} requests in {:.3} wall s ({:.0} req/wall-s)",
            out.stats.completed,
            s,
            out.stats.completed as f64 / s.max(1e-9),
        );
    }

    // Cartridge exclusivity: a hot-tape workload (every request on one
    // tape, singleton batches over 8 drives) with the single-cartridge
    // constraint on vs off — measures the resource-layer overhead and the
    // head-of-line serialization it surfaces.
    {
        let hot: Vec<Tape> = vec![Tape::from_sizes("HOT", &[1_000; 64])];
        let hot_mix = RequestMix::new(&hot);
        for (name, exclusive) in [("exclusive_on", true), ("exclusive_off", false)] {
            let xcfg = ReplayConfig {
                exclusive_tapes: exclusive,
                batcher: BatcherConfig {
                    window: std::time::Duration::from_millis(100),
                    max_batch: 1,
                    ..BatcherConfig::default()
                },
                ..cfg.clone()
            };
            let policy = scheduler_by_name("SimpleDP").unwrap();
            let mut model = PoissonArrivals::new(hot_mix.clone(), rate, duration, 7);
            let wall = Instant::now();
            let out = simulate(&xcfg, &hot, policy.as_ref(), &mut model);
            let s = wall.elapsed().as_secs_f64();
            assert!(out.stats.completed > 0, "exclusivity replay must serve requests");
            if exclusive {
                assert!(
                    out.stats.cartridge_parks > 0,
                    "hot singleton batches must collide on the cartridge"
                );
            } else {
                assert_eq!(out.stats.cartridge_parks, 0);
            }
            suite.record(BenchResult {
                name: format!("replay/{name}_hot_tape/SimpleDP"),
                iters: 1,
                median: s,
                mean: s,
                p10: s,
                p90: s,
            });
            println!(
                "    → {name}: {} requests, {} parks, cart-wait p99 {:.1}s in {:.3} wall s",
                out.stats.completed,
                out.stats.cartridge_parks,
                out.cartridge_wait.quantile(99.0),
                s,
            );
        }
    }

    // Mount pipeline: the same offered load with the robot-arm pool
    // bounded and LRU drive affinity on — measures the event-driven
    // pipeline's replay overhead and surfaces the remount economics.
    {
        let pipe_cfg = ReplayConfig {
            drive: DriveParams { n_arms: 2, ..DriveParams::default() },
            affinity: Affinity::Lru,
            ..cfg.clone()
        };
        let policy = scheduler_by_name("SimpleDP").unwrap();
        let mut model = PoissonArrivals::new(mix.clone(), rate, duration, 7);
        let wall = Instant::now();
        let out = simulate(&pipe_cfg, &catalog, policy.as_ref(), &mut model);
        let s = wall.elapsed().as_secs_f64();
        assert!(out.stats.completed > 0, "pipeline replay must serve requests");
        assert_eq!(
            out.stats.remount_hits + out.stats.remount_misses,
            out.stats.batches,
            "every batch must be classified hit or miss"
        );
        suite.record(BenchResult {
            name: "replay/mount_pipeline_2arms_lru/SimpleDP".to_string(),
            iters: 1,
            median: s,
            mean: s,
            p10: s,
            p90: s,
        });
        println!(
            "    → pipeline: {} requests, {} remount hits / {} misses, arm-wait p99 {:.1}s in {:.3} wall s",
            out.stats.completed,
            out.stats.remount_hits,
            out.stats.remount_misses,
            out.arm_wait.quantile(99.0),
            s,
        );
    }

    for policy_name in policies.iter().copied() {
        let policy = scheduler_by_name(policy_name).unwrap();
        for kind in arrivals.iter().copied() {
            let mut model: Box<dyn ArrivalModel> = match kind {
                "bursty" => Box::new(BurstyArrivals::new(mix.clone(), rate, duration, 7)),
                _ => Box::new(PoissonArrivals::new(mix.clone(), rate, duration, 7)),
            };
            let wall = Instant::now();
            let out = simulate(&cfg, &catalog, policy.as_ref(), model.as_mut());
            let s = wall.elapsed().as_secs_f64();
            assert!(out.stats.completed > 0, "replay must serve requests");
            assert_eq!(out.stats.completed, out.stats.submitted);
            suite.record(BenchResult {
                name: format!("replay/{kind}_{rate}rps_{duration}s/{policy_name}"),
                iters: 1,
                median: s,
                mean: s,
                p10: s,
                p90: s,
            });
            println!(
                "    → {} requests in {:.3} wall s ({:.0} virtual s; {:.0}× real time, {:.0} req/wall-s)",
                out.stats.completed,
                s,
                out.stats.makespan_us as f64 / 1e6,
                out.stats.makespan_us as f64 / 1e6 / s.max(1e-9),
                out.stats.completed as f64 / s.max(1e-9),
            );
        }
    }

    suite.write_csv("bench_replay.csv");
}
