//! Bench — the SimpleDP backend layer: every available backend
//! (pure-Rust dense always; the PJRT XLA engine with `--features xla`)
//! against the sparse exact solver on the same instances. With the `xla`
//! feature and artifacts present, adds the per-shape-bucket PJRT
//! compile/execute latencies; skips that section cleanly otherwise.
//!
//! `cargo bench --bench runtime_xla [-- --smoke]`

use tapesched::bench::{smoke_requested, BenchConfig, Suite};
use tapesched::runtime::{available_backends, SimpleDpBackend};
use tapesched::sched::simpledp_dense::dense_table;
use tapesched::sched::{Scheduler, SimpleDp};
use tapesched::testkit::{random_instance, InstanceGenConfig};
use tapesched::util::rng::Rng;

fn main() {
    let smoke = smoke_requested();
    let cfg_b = if smoke { BenchConfig::smoke() } else { BenchConfig::quick() };
    let mut suite = Suite::new();
    let mut rng = Rng::new(7);

    let backends = available_backends();
    println!(
        "backends: {}\n",
        backends.iter().map(|b| b.id()).collect::<Vec<_>>().join(", ")
    );

    let sizes: &[usize] = if smoke { &[8, 24] } else { &[8, 24, 64, 96] };
    for &k in sizes {
        let cfg = InstanceGenConfig {
            min_files: k,
            max_files: k,
            max_size: 40,
            max_gap: 25,
            max_x: 6,
            max_u: 20,
        };
        let inst = random_instance(&mut rng, &cfg);
        for b in &backends {
            suite.run(&format!("backend/{}/opt_cost/k={k}", b.id()), &cfg_b, || {
                b.opt_cost(&inst)
            });
            suite.run(&format!("backend/{}/opt_schedule/k={k}", b.id()), &cfg_b, || {
                b.opt_schedule(&inst)
            });
        }
        suite.run(&format!("rust/dense_table/k={k}"), &cfg_b, || dense_table(&inst));
        suite.run(&format!("rust/sparse_simpledp/k={k}"), &cfg_b, || {
            SimpleDp.schedule(&inst)
        });
        println!();
    }

    #[cfg(feature = "xla")]
    xla_bucket_bench(&mut suite, smoke);

    suite.write_csv("bench_runtime_xla.csv");
}

/// Per-bucket PJRT latencies (compile-once cost recorded separately).
#[cfg(feature = "xla")]
fn xla_bucket_bench(suite: &mut Suite, smoke: bool) {
    use tapesched::bench::once;
    use tapesched::runtime::{XlaSimpleDp, ARTIFACT_DIR};

    let backend = match XlaSimpleDp::new(ARTIFACT_DIR) {
        Ok(b) if !b.buckets().is_empty() => b,
        _ => {
            println!("runtime_xla: no artifacts (run `make artifacts`) — skipping PJRT section");
            return;
        }
    };
    let cfg_b = if smoke { BenchConfig::smoke() } else { BenchConfig::quick() };
    let mut rng = Rng::new(7);
    for bucket in backend.buckets().to_vec() {
        // An instance that fills ~3/4 of the bucket.
        let k_target = (bucket.k * 3 / 4).max(2);
        let cfg = InstanceGenConfig {
            min_files: k_target,
            max_files: k_target,
            max_size: 40,
            max_gap: 25,
            // keep n safely under the bucket's NS
            max_x: ((bucket.ns - 1) / k_target.max(1)).clamp(1, 8) as u64,
            max_u: 20,
        };
        let inst = random_instance(&mut rng, &cfg);
        assert!(bucket.fits(&inst));

        // First call = compile + execute; record separately.
        let (_, compile_r) = once(
            &format!("xla/compile+run/{}", bucket.artifact()),
            || backend.table(&inst).unwrap(),
        );
        suite.record(compile_r);
        suite.run(&format!("xla/execute/{}", bucket.artifact()), &cfg_b, || {
            backend.table(&inst).unwrap()
        });
        println!();
    }
}
