//! Bench — the XLA evaluation engine: PJRT execute latency per shape
//! bucket vs the exact i128 dense implementation on the same instances,
//! plus compile-once cost. Skips cleanly when artifacts are absent.

use tapesched::bench::{bench, once, BenchConfig, Suite};
use tapesched::runtime::{XlaSimpleDp, ARTIFACT_DIR};
use tapesched::sched::simpledp_dense::dense_table;
use tapesched::sched::{Scheduler, SimpleDp};
use tapesched::testkit::{random_instance, InstanceGenConfig};
use tapesched::util::rng::Rng;

fn main() {
    let backend = match XlaSimpleDp::new(ARTIFACT_DIR) {
        Ok(b) if !b.buckets().is_empty() => b,
        _ => {
            println!("runtime_xla: no artifacts (run `make artifacts`) — skipping");
            return;
        }
    };
    let mut suite = Suite::new();
    let mut rng = Rng::new(7);

    for bucket in backend.buckets().to_vec() {
        // An instance that fills ~3/4 of the bucket.
        let k_target = (bucket.k * 3 / 4).max(2);
        let cfg = InstanceGenConfig {
            min_files: k_target,
            max_files: k_target,
            max_size: 40,
            max_gap: 25,
            // keep n safely under the bucket's NS
            max_x: ((bucket.ns - 1) / k_target.max(1)).clamp(1, 8) as u64,
            max_u: 20,
        };
        let inst = random_instance(&mut rng, &cfg);
        assert!(bucket.fits(&inst));

        // First call = compile + execute; record separately.
        let (_, compile_r) = once(
            &format!("xla/compile+run/{}", bucket.artifact()),
            || backend.table(&inst).unwrap(),
        );
        suite.record(compile_r);

        let cfg_b = BenchConfig::quick();
        suite.run(&format!("xla/execute/{}", bucket.artifact()), &cfg_b, || {
            backend.table(&inst).unwrap()
        });
        suite.run(&format!("rust/dense_table/k={}", inst.k()), &cfg_b, || {
            dense_table(&inst)
        });
        suite.run(&format!("rust/sparse_simpledp/k={}", inst.k()), &cfg_b, || {
            SimpleDp.schedule(&inst)
        });
        println!();
    }
    suite.write_csv("bench_runtime_xla.csv");
}
