//! Bench — the serving layer: batcher throughput, end-to-end coordinator
//! throughput per policy, and drive-pool scaling of the library simulator.

use std::sync::Arc;
use std::time::Instant;

use tapesched::bench::{bench, smoke_requested, BenchConfig, BenchResult, Suite};
use tapesched::coordinator::{Batcher, BatcherConfig, Coordinator, CoordinatorConfig, ReadRequest};
use tapesched::dataset::{generate_dataset, GeneratorConfig};
use tapesched::sched::scheduler_by_name;
use tapesched::sim::{DriveParams, LibrarySim, TapeJob};
use tapesched::util::rng::Rng;

fn main() {
    let smoke = smoke_requested();
    let mut suite = Suite::new();

    // --- batcher micro-bench: push+pop throughput -----------------------
    let cfg = if smoke { BenchConfig::smoke() } else { BenchConfig::quick() };
    suite.run("batcher/push_pop_10k", &cfg, || {
        let mut b = Batcher::new(BatcherConfig {
            window: std::time::Duration::ZERO,
            max_batch: 256,
            ..BatcherConfig::default()
        });
        let t0 = Instant::now();
        for id in 0..10_000u64 {
            b.push(["A", "B", "C", "D"][(id % 4) as usize], (id % 64) as usize, id, t0);
        }
        let mut n = 0;
        while let Some(batch) = b.pop_ready(t0, true) {
            n += batch.n_requests();
        }
        assert_eq!(n, 10_000);
    });

    // --- coordinator end-to-end throughput per policy -------------------
    let ds = if smoke {
        generate_dataset(&GeneratorConfig {
            n_tapes: 8,
            nf: (40, 60.0, 70.0, 150),
            nreq: (10, 25.0, 30.0, 60),
            n: (20, 60.0, 70.0, 180),
            ..Default::default()
        })
    } else {
        generate_dataset(&GeneratorConfig { n_tapes: 24, ..Default::default() })
    };
    let policies: &[&str] =
        if smoke { &["GS", "SimpleDP"] } else { &["GS", "SimpleDP", "LogDP(1)"] };
    for policy_name in policies.iter().copied() {
        let n_req = if smoke { 500u64 } else { 4_000u64 };
        let e2e_cfg = if smoke {
            BenchConfig::smoke()
        } else {
            BenchConfig {
                warmup: std::time::Duration::ZERO,
                measure: std::time::Duration::from_secs(2),
                max_iters: 5,
                min_iters: 2,
            }
        };
        let r = bench(
            &format!("coordinator/e2e_{n_req}req/{policy_name}"),
            &e2e_cfg,
            || {
                let coord = Coordinator::start(
                    CoordinatorConfig {
                        n_drives: 8,
                        batcher: BatcherConfig {
                            window: std::time::Duration::from_millis(2),
                            max_batch: 256,
                            ..BatcherConfig::default()
                        },
                        drive: DriveParams::default(),
                        ..CoordinatorConfig::default()
                    },
                    ds.tapes.iter().map(|t| t.tape.clone()),
                    Arc::from(scheduler_by_name(policy_name).unwrap()),
                );
                let mut rng = Rng::new(5);
                for id in 0..n_req {
                    let t = &ds.tapes[rng.below(ds.tapes.len() as u64) as usize];
                    coord
                        .submit(ReadRequest {
                            id,
                            tape: t.tape.name.clone(),
                            file_index: rng.below(t.tape.n_files() as u64) as usize,
                        })
                        .expect("bench requests are routable");
                }
                let (completions, _) = coord.finish();
                assert_eq!(completions.len() as u64, n_req);
            },
        );
        let req_per_s = n_req as f64 / r.median;
        println!("    → {:.0} requests/s through the full stack", req_per_s);
        suite.record(r);
    }

    // --- library sim: drive-pool scaling ---------------------------------
    let policy = scheduler_by_name("SimpleDP").unwrap();
    let mut rng = Rng::new(11);
    let mut by_size: Vec<_> = ds.tapes.iter().collect();
    by_size.sort_by_key(|t| t.n_req());
    let jobs: Vec<TapeJob> = by_size
        .iter()
        .take(16)
        .map(|t| TapeJob {
            tape_name: t.tape.name.clone(),
            arrival_s: rng.f64() * 10.0,
            instance: t.instance(0).unwrap(),
        })
        .collect();
    let drive_pools: &[usize] = if smoke { &[1, 4] } else { &[1, 4, 16] };
    for &n_drives in drive_pools {
        let sim = LibrarySim::new(DriveParams::default(), n_drives, policy.as_ref());
        let jobs2 = jobs.clone();
        let t0 = Instant::now();
        let (_, m) = sim.run(jobs2);
        let s = t0.elapsed().as_secs_f64();
        suite.record(BenchResult {
            name: format!("library_sim/16jobs/{n_drives}drives"),
            iters: 1,
            median: s,
            mean: s,
            p10: s,
            p90: s,
        });
        println!(
            "    → makespan {:.0}s, mean latency {:.0}s, utilization {:.0}%",
            m.makespan_s,
            m.mean_latency_s,
            m.drive_utilization * 100.0
        );
    }

    suite.write_csv("bench_coordinator.csv");
}
