#!/usr/bin/env bash
# One-command gate for every PR: formatting check (advisory unless
# FMT_STRICT=1, since rustfmt may be absent from offline toolchains),
# the tier-1 verify, and compile gates for benches and examples.
set -euo pipefail
cd "$(dirname "$0")/.."

if cargo fmt --version >/dev/null 2>&1; then
    if ! cargo fmt --all -- --check; then
        echo "ci: formatting drift detected (run \`cargo fmt --all\` to fix)" >&2
        if [ "${FMT_STRICT:-0}" = "1" ]; then
            exit 1
        fi
    fi
else
    echo "ci: rustfmt unavailable; skipping fmt-check" >&2
fi

cargo build --release
cargo test -q
cargo bench --no-run
cargo build --examples

# Lint gate: clippy with -D warnings (advisory unless CLIPPY_STRICT=1,
# mirroring the fmt gate — offline toolchains may ship without clippy).
if cargo clippy --version >/dev/null 2>&1; then
    if ! cargo clippy -q -- -D warnings; then
        echo "ci: clippy findings detected (run \`cargo clippy\` to inspect)" >&2
        if [ "${CLIPPY_STRICT:-0}" = "1" ]; then
            exit 1
        fi
    fi
else
    echo "ci: clippy unavailable; skipping lint gate" >&2
fi

# Replay gate: a seeded 2-second virtual replay must emit a parseable,
# non-empty QoS report with a sane percentile ladder per policy.
./target/release/tapesched replay --arrivals poisson --rate 50 --duration 2 \
    --policy GS,SimpleDP --seed 7 --tapes 12 --out /tmp/replay_ci.json
python3 - /tmp/replay_ci.json <<'EOF'
import json, sys
with open(sys.argv[1]) as f:
    doc = json.load(f)
reports = doc["reports"]
assert reports, "no QoS reports emitted"
for r in reports:
    assert r["completed"] > 0, f"policy {r['policy']} completed nothing"
    lat = r["latency"]
    assert 0 <= lat["p50_s"] <= lat["p95_s"] <= lat["p99_s"] <= lat["p999_s"], lat
print(f"ci: replay smoke OK ({len(reports)} policies)")
EOF

# Sharded replay gate: the per-shard QoS JSON must parse, every shard must
# have served requests, and every percentile ladder (fleet + per shard)
# must be monotone.
./target/release/tapesched replay --shards 4 --smoke --seed 7 \
    --out /tmp/replay_shard_ci.json
python3 - /tmp/replay_shard_ci.json <<'EOF'
import json, sys
with open(sys.argv[1]) as f:
    doc = json.load(f)
reports = doc["reports"]
assert reports, "no QoS reports emitted"
def ladder_ok(lat):
    return 0 <= lat["p50_s"] <= lat["p95_s"] <= lat["p99_s"] <= lat["p999_s"]
for r in reports:
    assert r["shards"] == 4, r["shards"]
    shards = r["per_shard"]
    assert len(shards) == 4, f"expected 4 shard sections, got {len(shards)}"
    assert sum(s["completed"] for s in shards) == r["completed"]
    for s in shards:
        assert s["completed"] > 0, f"shard {s['shard']} served no requests"
        assert ladder_ok(s["latency"]), (s["shard"], s["latency"])
    assert ladder_ok(r["latency"]), r["latency"]
print(f"ci: shard smoke OK (4 shards, {reports[0]['completed']} requests)")
EOF

echo "ci: all gates green"
