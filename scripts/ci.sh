#!/usr/bin/env bash
# One-command gate for every PR: formatting check (advisory unless
# FMT_STRICT=1, since rustfmt may be absent from offline toolchains),
# the tier-1 verify, and compile gates for benches and examples.
set -euo pipefail
cd "$(dirname "$0")/.."

if cargo fmt --version >/dev/null 2>&1; then
    if ! cargo fmt --all -- --check; then
        echo "ci: formatting drift detected (run \`cargo fmt --all\` to fix)" >&2
        if [ "${FMT_STRICT:-0}" = "1" ]; then
            exit 1
        fi
    fi
else
    echo "ci: rustfmt unavailable; skipping fmt-check" >&2
fi

cargo build --release
cargo test -q
cargo bench --no-run
cargo build --examples

# Replay gate: a seeded 2-second virtual replay must emit a parseable,
# non-empty QoS report with a sane percentile ladder per policy.
./target/release/tapesched replay --arrivals poisson --rate 50 --duration 2 \
    --policy GS,SimpleDP --seed 7 --tapes 12 --out /tmp/replay_ci.json
python3 - /tmp/replay_ci.json <<'EOF'
import json, sys
with open(sys.argv[1]) as f:
    doc = json.load(f)
reports = doc["reports"]
assert reports, "no QoS reports emitted"
for r in reports:
    assert r["completed"] > 0, f"policy {r['policy']} completed nothing"
    lat = r["latency"]
    assert 0 <= lat["p50_s"] <= lat["p95_s"] <= lat["p99_s"] <= lat["p999_s"], lat
print(f"ci: replay smoke OK ({len(reports)} policies)")
EOF

echo "ci: all gates green"
