#!/usr/bin/env bash
# One-command gate for every PR: formatting check (advisory unless
# FMT_STRICT=1, since rustfmt may be absent from offline toolchains),
# the tier-1 verify, and compile gates for benches and examples.
set -euo pipefail
cd "$(dirname "$0")/.."

if cargo fmt --version >/dev/null 2>&1; then
    if ! cargo fmt --all -- --check; then
        echo "ci: formatting drift detected (run \`cargo fmt --all\` to fix)" >&2
        if [ "${FMT_STRICT:-0}" = "1" ]; then
            exit 1
        fi
    fi
else
    echo "ci: rustfmt unavailable; skipping fmt-check" >&2
fi

cargo build --release
cargo test -q
cargo bench --no-run
cargo build --examples

# Static-analysis gate, deliberately ahead of clippy: the built-in
# determinism & invariant linter (`tapesched audit`, rules in
# rust/README.md) must report zero findings and zero unused waivers on
# the shipped tree. Enforced by default; AUDIT_STRICT=0 downgrades it to
# advisory while iterating on a new rule.
if ! ./target/release/tapesched audit rust/src; then
    echo "ci: audit findings (fix, or waive with \`audit:allow(rule-id) reason\`;" \
         "stale waivers: \`tapesched audit --fix-waivers\`)" >&2
    if [ "${AUDIT_STRICT:-1}" = "1" ]; then
        exit 1
    fi
fi

# Lint gate: clippy with -D warnings. Enforced by default (CLIPPY_STRICT=0
# downgrades it to advisory for local iteration); skipped only when the
# toolchain ships without clippy.
if cargo clippy --version >/dev/null 2>&1; then
    if ! cargo clippy -q -- -D warnings; then
        echo "ci: clippy findings detected (run \`cargo clippy\` to inspect)" >&2
        if [ "${CLIPPY_STRICT:-1}" = "1" ]; then
            exit 1
        fi
    fi
else
    echo "ci: clippy unavailable; skipping lint gate" >&2
fi

# Advisory sanitizer jobs — opt-in and never fatal. They target the two
# places static rules reach weakest: the condvar dispatcher
# (coordinator::service tests exercise park/unpark, drain hand-off, and
# poison recovery) and the framed codec + serving loops under net::.
# Both need a nightly toolchain; each skips gracefully when the
# toolchain or component is absent (offline stable images).
if [ "${MIRI:-0}" = "1" ]; then
    if cargo +nightly miri --version >/dev/null 2>&1; then
        echo "ci: advisory Miri pass (coordinator::service + net::wire tests)" >&2
        MIRIFLAGS="-Zmiri-disable-isolation" \
            cargo +nightly miri test -q --lib coordinator::service:: net::wire:: \
            || echo "ci: Miri reported issues (advisory, not failing the gate)" >&2
    else
        echo "ci: MIRI=1 but nightly miri is unavailable; skipping" >&2
    fi
fi
if [ "${TSAN:-0}" = "1" ]; then
    host="$(rustc -vV | sed -n 's/^host: //p')"
    if cargo +nightly --version >/dev/null 2>&1 \
        && rustup component list --toolchain nightly 2>/dev/null \
            | grep -q '^rust-src (installed)'; then
        echo "ci: advisory ThreadSanitizer pass (coordinator::service + net tests)" >&2
        RUSTFLAGS="-Zsanitizer=thread" \
            cargo +nightly test -q -Zbuild-std --target "$host" \
            --lib coordinator::service:: net:: \
            || echo "ci: TSan reported issues (advisory, not failing the gate)" >&2
    else
        echo "ci: TSAN=1 but nightly rust-src is unavailable; skipping" >&2
    fi
fi

# Replay gate: a seeded 2-second virtual replay must emit a parseable,
# non-empty QoS report with a sane percentile ladder per policy.
./target/release/tapesched replay --arrivals poisson --rate 50 --duration 2 \
    --policy GS,SimpleDP --seed 7 --tapes 12 --out /tmp/replay_ci.json
python3 - /tmp/replay_ci.json <<'EOF'
import json, sys
with open(sys.argv[1]) as f:
    doc = json.load(f)
reports = doc["reports"]
assert reports, "no QoS reports emitted"
for r in reports:
    assert r["completed"] > 0, f"policy {r['policy']} completed nothing"
    lat = r["latency"]
    assert 0 <= lat["p50_s"] <= lat["p95_s"] <= lat["p99_s"] <= lat["p999_s"], lat
print(f"ci: replay smoke OK ({len(reports)} policies)")
EOF

# Sharded replay gate: the per-shard QoS JSON must parse, every shard must
# have served requests, and every percentile ladder (fleet + per shard)
# must be monotone.
./target/release/tapesched replay --shards 4 --smoke --seed 7 \
    --out /tmp/replay_shard_ci.json
python3 - /tmp/replay_shard_ci.json <<'EOF'
import json, sys
with open(sys.argv[1]) as f:
    doc = json.load(f)
reports = doc["reports"]
assert reports, "no QoS reports emitted"
def ladder_ok(lat):
    return 0 <= lat["p50_s"] <= lat["p95_s"] <= lat["p99_s"] <= lat["p999_s"]
for r in reports:
    assert r["shards"] == 4, r["shards"]
    shards = r["per_shard"]
    assert len(shards) == 4, f"expected 4 shard sections, got {len(shards)}"
    assert sum(s["completed"] for s in shards) == r["completed"]
    for s in shards:
        assert s["completed"] > 0, f"shard {s['shard']} served no requests"
        assert ladder_ok(s["latency"]), (s["shard"], s["latency"])
    assert ladder_ok(r["latency"]), r["latency"]
print(f"ci: shard smoke OK (4 shards, {reports[0]['completed']} requests)")
EOF

# Mount-pipeline gates.
# (a) Byte-compatibility: explicit default flags must not move a byte —
#     `--arms 0 --affinity none` against the flag-free default (both with
#     exclusivity at its default), and `--exclusive-tapes off --arms 0
#     --affinity none` IS the legacy fixed mount-cost path: its JSON must
#     be byte-identical to the bare `--exclusive-tapes off` run (the PR 4
#     report format, whose key set the report layer only extends when the
#     pipeline / exclusivity are active) and must leak neither pipeline
#     nor cartridge keys, while the exclusive default carries the new
#     cartridge sections.
./target/release/tapesched replay --shards 4 --smoke --seed 7 \
    --out /tmp/replay_arm_default.json
./target/release/tapesched replay --shards 4 --smoke --seed 7 \
    --arms 0 --affinity none --out /tmp/replay_arm_flags.json
cmp /tmp/replay_arm_default.json /tmp/replay_arm_flags.json
./target/release/tapesched replay --shards 4 --smoke --seed 7 \
    --exclusive-tapes off --out /tmp/replay_legacy_default.json
./target/release/tapesched replay --shards 4 --smoke --seed 7 \
    --exclusive-tapes off --arms 0 --affinity none --out /tmp/replay_legacy_flags.json
cmp /tmp/replay_legacy_default.json /tmp/replay_legacy_flags.json
python3 - /tmp/replay_legacy_default.json /tmp/replay_arm_default.json <<'EOF'
import json, sys
legacy = json.load(open(sys.argv[1]))["reports"][0]
for key in ("arms", "affinity", "remount_hits", "arm_wait", "mount_wait", "drive_wait",
            "exclusive_tapes", "cartridge_parks", "cartridge_wait"):
    assert key not in legacy, f"legacy report leaked key {key}"
    assert key not in legacy["per_shard"][0], f"legacy shard section leaked {key}"
exclusive = json.load(open(sys.argv[2]))["reports"][0]
assert exclusive["exclusive_tapes"] is True, "default run must enforce exclusivity"
assert "cartridge_wait" in exclusive and "cartridge_parks" in exclusive
assert "cartridge_wait" in exclusive["per_shard"][0]
assert "arm_wait" not in exclusive, "no pipeline keys without arms/affinity"
print("ci: arm gate (a) OK — legacy path byte-stable, cartridge keys gated")
EOF

# (b) Fidelity: one robot arm + LRU affinity on the bursty workload. The
#     geometry is chosen so the assertions are structural, not tuned:
#     128 drives exceed the total batch count (--max-batch 1 pins one
#     request per batch), so no batch ever waits for a drive, while the
#     serialized mount work (~60 batches x 60 s) exceeds the 600 s arrival
#     window, so mounts MUST queue on the single arm. Hence: remount hits
#     once tapes stay threaded, arm-wait p99 >= drive-wait p99 (= 0), and
#     a strictly worse latency p99.9 than the unconstrained robot.
#     (`--exclusive-tapes off` pins the PR 4 geometry: the two runs must
#     differ by the arm bound alone, not by cartridge serialization.)
./target/release/tapesched replay --arrivals bursty --rate 0.1 --duration 600 \
    --tapes 4 --drives 128 --max-batch 1 --seed 7 --exclusive-tapes off \
    --out /tmp/replay_arm0.json
./target/release/tapesched replay --arrivals bursty --rate 0.1 --duration 600 \
    --tapes 4 --drives 128 --max-batch 1 --seed 7 --exclusive-tapes off \
    --arms 1 --affinity lru --out /tmp/replay_arm1.json
python3 - /tmp/replay_arm0.json /tmp/replay_arm1.json <<'EOF'
import json, sys
base = json.load(open(sys.argv[1]))["reports"][0]
armed = json.load(open(sys.argv[2]))["reports"][0]
assert "arm_wait" not in base, "unconstrained baseline must stay legacy"
assert armed["arms"] == 1 and armed["affinity"] == "lru", (armed["arms"], armed["affinity"])
assert armed["remount_hits"] > 0, "LRU affinity must score remount hits"
assert armed["remount_hits"] + armed["remount_misses"] == armed["batches"]
assert armed["arm_wait"]["max_s"] > 0, "the single arm must queue some op"
assert armed["arm_wait"]["p99_s"] >= armed["drive_wait"]["p99_s"], (
    armed["arm_wait"]["p99_s"], armed["drive_wait"]["p99_s"])
assert armed["latency"]["p999_s"] > base["latency"]["p999_s"], (
    armed["latency"]["p999_s"], base["latency"]["p999_s"])
assert armed["completed"] == base["completed"], "no request may be lost"
print(f"ci: arm gate (b) OK — {armed['remount_hits']} hits, "
      f"arm p99 {armed['arm_wait']['p99_s']:.1f}s, "
      f"p99.9 {base['latency']['p999_s']:.1f}s -> {armed['latency']['p999_s']:.1f}s")
EOF

# Cartridge-exclusivity gate: a hot-tape workload (every request on one
# tape, singleton batches over 8 drives) must show nonzero cartridge_wait
# and a strictly worse latency p99.9 than the same run with
# --exclusive-tapes off — the head-of-line effect the single-cartridge
# constraint exists to surface. Same request count in both runs.
./target/release/tapesched replay --arrivals poisson --rate 2 --duration 30 \
    --tapes 1 --drives 8 --max-batch 1 --seed 7 --exclusive-tapes off \
    --out /tmp/replay_excl_off.json
./target/release/tapesched replay --arrivals poisson --rate 2 --duration 30 \
    --tapes 1 --drives 8 --max-batch 1 --seed 7 \
    --out /tmp/replay_excl_on.json
python3 - /tmp/replay_excl_off.json /tmp/replay_excl_on.json <<'EOF'
import json, sys
off = json.load(open(sys.argv[1]))["reports"][0]
on = json.load(open(sys.argv[2]))["reports"][0]
assert "cartridge_wait" not in off, "exclusive-tapes off must stay legacy"
assert on["exclusive_tapes"] is True
assert on["cartridge_parks"] > 0, "the hot tape must park batches"
assert on["cartridge_wait"]["max_s"] > 0, "parked batches must wait"
assert on["latency"]["p999_s"] > off["latency"]["p999_s"], (
    on["latency"]["p999_s"], off["latency"]["p999_s"])
assert on["completed"] == off["completed"], "no request may be lost"
print(f"ci: exclusivity gate OK — {on['cartridge_parks']} parks, "
      f"cart wait max {on['cartridge_wait']['max_s']:.1f}s, "
      f"p99.9 {off['latency']['p999_s']:.1f}s -> {on['latency']['p999_s']:.1f}s")
EOF

# Networked-cluster gate (a) — loopback parity: the same seeded request
# stream through the in-process Cluster and through a loopback
# coordinator/worker fleet (every submit a framed TCP round trip) must
# agree on every virtual-time number: counters identical, tour costs
# identical (the wire ships IEEE-754 bits, and both modes sum service
# times in request-id order, so even the printed floats must match
# exactly). Only wall-clock latency — the RPC tax — may differ.
./target/release/tapesched rpc-tax --policy GS,SimpleDP --requests 240 \
    --seed 7 --out /tmp/rpc_tax_ci.json
python3 - /tmp/rpc_tax_ci.json <<'EOF'
import json, sys
doc = json.load(open(sys.argv[1]))
assert doc["schema"] == "tapesched-rpc-tax-v1", doc.get("schema")
assert "kill_report" not in doc, "no kill was requested"
reports = doc["rpc_reports"]
assert len(reports) == 2, f"expected 2 policies, got {len(reports)}"
for r in reports:
    ip, lb = r["in_process"], r["loopback"]
    assert ip["submitted"] == lb["submitted"] == doc["requests"], (
        r["policy"], ip["submitted"], lb["submitted"])
    assert ip["completed"] == lb["completed"] == doc["requests"], (
        r["policy"], ip["completed"], lb["completed"])
    assert ip["shed"] == lb["shed"] == 0, (r["policy"], ip["shed"], lb["shed"])
    assert ip["dropped"] == lb["dropped"] == 0, (r["policy"], ip["dropped"], lb["dropped"])
    assert ip["tour_cost_s"] == lb["tour_cost_s"], (
        f"policy {r['policy']}: tour cost moved across the wire "
        f"({ip['tour_cost_s']} vs {lb['tour_cost_s']})")
    for d in (ip, lb):
        assert 0 <= d["p50_latency_s"] <= d["p99_latency_s"] <= d["p999_latency_s"], d
    assert isinstance(r["p999_delta_s"], float), r["p999_delta_s"]
print(f"ci: net parity gate OK ({len(reports)} policies, "
      f"tour {reports[0]['in_process']['tour_cost_s']:.1f}s both modes)")
EOF

# Networked-cluster gate (b) — worker crash: one worker is cut after its
# first accepted request. That request must be shed (not forgotten),
# later submits to the dead shard must be dropped by the driver (the
# coordinator answers ShardDown, a non-retryable refusal — not Busy),
# every arrival must be accounted accepted-or-dropped, and the
# fleet-wide drain invariant `submitted = completed + shed` must hold.
./target/release/tapesched rpc-tax --policy GS --requests 120 --seed 7 \
    --kill-after 1 --out /tmp/rpc_tax_kill_ci.json
python3 - /tmp/rpc_tax_kill_ci.json <<'EOF'
import json, sys
doc = json.load(open(sys.argv[1]))
k = doc["kill_report"]
assert k["drain_invariant_holds"] is True, k
assert k["shed"] >= 1, "the killed worker's accepted request must be shed"
assert k["submitted"] == k["completed"] + k["shed"], (
    k["submitted"], k["completed"], k["shed"])
assert k["submitted"] + k["dropped"] == doc["requests"], (
    k["submitted"], k["dropped"], doc["requests"])
print(f"ci: net kill gate OK — shard {k['kill_shard']} cut, "
      f"{k['shed']} shed, {k['dropped']} dropped, invariant holds")
EOF

# Observability gate (a) — request-lifecycle tracing: a traced replay
# must dump a span stream whose chains check out (nine contiguous,
# monotone spans per completed request), and the spans subcommand must
# render every stage of the pipeline taxonomy in its breakdown.
./target/release/tapesched replay --shards 4 --smoke --seed 7 \
    --trace-out /tmp/obs_trace_ci.jsonl --out /tmp/replay_traced_ci.json
./target/release/tapesched spans --in /tmp/obs_trace_ci.jsonl --check \
    > /tmp/obs_spans_ci.txt
python3 - /tmp/obs_spans_ci.txt <<'EOF'
import sys
text = open(sys.argv[1]).read()
for stage in ("submit", "route", "batch_seal", "drive_wait", "cartridge_wait",
              "arm_wait", "mount", "exec", "complete"):
    assert stage in text, f"breakdown missing stage {stage}:\n{text}"
print("ci: obs trace gate OK (all nine stages rendered)")
EOF

# Observability gate (b) — observer purity: the recorder must be a pure
# observer, so the traced run's QoS JSON must be byte-identical to the
# untraced default run of the same flags (reuses the arm gate artifact).
cmp /tmp/replay_arm_default.json /tmp/replay_traced_ci.json
echo "ci: obs purity gate OK (tracing moved no byte of the QoS JSON)"

# Observability gate (c) — the scrape endpoint: a served run exposing
# /metrics must publish Prometheus text whose counters land exactly on
# the request count once the drain finishes (the linger window holds the
# final page open for the scraper).
./target/release/tapesched serve --requests 400 --seed 7 \
    --metrics-listen 127.0.0.1:0 --metrics-linger-ms 8000 \
    > /tmp/obs_serve_ci.out 2> /tmp/obs_serve_ci.err &
SERVE_PID=$!
python3 - /tmp/obs_serve_ci.err 400 <<'EOF'
import re, sys, time, urllib.request
errpath, want = sys.argv[1], int(sys.argv[2])
deadline = time.time() + 60
url = None
while time.time() < deadline and url is None:
    m = re.search(r"metrics exposition on (http://\S+)", open(errpath).read())
    if m:
        url = m.group(1)
    else:
        time.sleep(0.1)
assert url, "serve never announced its exposition endpoint"
page = None
while time.time() < deadline:
    try:
        page = urllib.request.urlopen(url, timeout=5).read().decode()
        if f'tapesched_completed_total{{shard="0"}} {want}' in page:
            break
    except OSError:
        pass
    time.sleep(0.2)
assert page is not None, "scrape never succeeded"
assert f'tapesched_submitted_total{{shard="0"}} {want}' in page, page
assert f'tapesched_completed_total{{shard="0"}} {want}' in page, page
assert '# TYPE tapesched_latency_seconds histogram' in page, page
assert f'tapesched_latency_seconds_bucket{{shard="0",le="+Inf"}} {want}' in page, page
assert f'tapesched_latency_seconds_count{{shard="0"}} {want}' in page, page
print(f"ci: obs scrape gate OK ({want} requests visible at {url})")
EOF
wait "$SERVE_PID"

# Observability gate (d) — push-based telemetry: the closed-loop driver
# pays two round trips per request in pull mode (MetricsPull + Submit)
# and one in push mode (the gauge is fed by the coordinator's push
# stream), so push-mode submit throughput must be strictly higher.
./target/release/tapesched rpc-tax --policy GS --requests 240 --seed 7 \
    --push-metrics --out /tmp/rpc_tax_push_ci.json
python3 - /tmp/rpc_tax_push_ci.json <<'EOF'
import json, sys
doc = json.load(open(sys.argv[1]))
p = doc["push_report"]
assert p["pull_submits_per_s"] > 0 and p["push_submits_per_s"] > 0, p
assert p["push_submits_per_s"] > p["pull_submits_per_s"], (
    f"push must beat pull: {p['push_submits_per_s']} vs {p['pull_submits_per_s']}")
print(f"ci: obs push gate OK — pull {p['pull_submits_per_s']:.0f} -> "
      f"push {p['push_submits_per_s']:.0f} submits/s "
      f"({p['push_submits_per_s'] / p['pull_submits_per_s']:.2f}x)")
EOF

# Raw-speed gate (a) — parallel replay determinism: fanning the sharded
# smoke replay over 4 worker threads must not move a byte of the QoS
# JSON vs the single-threaded run of the same flags (the arm gate
# artifact above, which ran with the default --threads 1).
./target/release/tapesched replay --shards 4 --smoke --seed 7 \
    --threads 4 --out /tmp/replay_threads4_ci.json
cmp /tmp/replay_arm_default.json /tmp/replay_threads4_ci.json
echo "ci: parallel replay gate OK (4 threads byte-identical to 1)"

# Raw-speed gate (b) — incremental DP re-solve: the property tests pin
# the table to the full solver bit for bit over random grow sequences,
# and require both repair paths to fire (appends extended in place,
# non-appends falling back to a rebuild).
cargo test -q incremental_
echo "ci: incremental DP gate OK (bit-equal property tests green)"

# Raw-speed gate (c) — skewed-ring work stealing: a 9-shard ring over 3
# workers (the consistent-hash spread is uneven at this geometry), with
# and without --steal, must emit the same bytes as the single-threaded
# run — shard ownership is a pure function of the seeded pre-pass, so
# LPT assignment and epoch stealing move work, never results. The
# balance evidence (per-worker busy times, max/min ratio, round-robin
# counterfactual, steal count) must land on stderr, never in the JSON.
./target/release/tapesched replay --shards 9 --smoke --seed 7 \
    --threads 1 --out /tmp/replay_skew1_ci.json
./target/release/tapesched replay --shards 9 --smoke --seed 7 \
    --threads 3 --out /tmp/replay_skew3_ci.json 2> /tmp/replay_skew3_ci.err
./target/release/tapesched replay --shards 9 --smoke --seed 7 \
    --threads 3 --steal --out /tmp/replay_skew3_steal_ci.json \
    2> /tmp/replay_skew3_steal_ci.err
cmp /tmp/replay_skew1_ci.json /tmp/replay_skew3_ci.json
cmp /tmp/replay_skew1_ci.json /tmp/replay_skew3_steal_ci.json
grep -q "worker balance (Weighted)" /tmp/replay_skew3_ci.err
grep -q "worker balance (Stolen)" /tmp/replay_skew3_steal_ci.err
echo "ci: work-stealing gate OK (9 shards x {1,3,3+steal} byte-identical, balance on stderr)"

# Raw-speed gate (d) — incremental DP on the serving path: the smoke
# serve with --backend incremental must record nonzero table appends
# (growing same-tape backlogs repaired in place instead of re-solved)
# and keep the drain invariant submitted = completed + shed intact. The
# bit-equality of served service times against the fresh solve is pinned
# by the coordinator::service property test (runs under `cargo test`
# above) and by the debug assertion inside the backend itself.
./target/release/tapesched serve --requests 400 --seed 7 \
    --backend incremental > /tmp/serve_incr_ci.out
python3 - /tmp/serve_incr_ci.out <<'EOF'
import re, sys
text = open(sys.argv[1]).read()
m = re.search(r"incremental appends/rebuilds = (\d+) / (\d+)", text)
assert m, f"no incremental counter line in:\n{text}"
appends, rebuilds = int(m.group(1)), int(m.group(2))
assert appends > 0, "serving path never appended a column"
d = re.search(r"drain submitted/completed/shed = (\d+) / (\d+) / (\d+)", text)
assert d, f"no drain triple in:\n{text}"
sub, comp, shed = (int(x) for x in d.groups())
assert sub == comp + shed, (sub, comp, shed)
print(f"ci: serving-incremental gate OK ({appends} appends, {rebuilds} rebuilds, "
      f"{sub} = {comp} + {shed})")
EOF

echo "ci: all gates green"
