#!/usr/bin/env bash
# One-command gate for every PR: formatting check (advisory unless
# FMT_STRICT=1, since rustfmt may be absent from offline toolchains),
# the tier-1 verify, and compile gates for benches and examples.
set -euo pipefail
cd "$(dirname "$0")/.."

if cargo fmt --version >/dev/null 2>&1; then
    if ! cargo fmt --all -- --check; then
        echo "ci: formatting drift detected (run \`cargo fmt --all\` to fix)" >&2
        if [ "${FMT_STRICT:-0}" = "1" ]; then
            exit 1
        fi
    fi
else
    echo "ci: rustfmt unavailable; skipping fmt-check" >&2
fi

cargo build --release
cargo test -q
cargo bench --no-run
cargo build --examples

echo "ci: all gates green"
