"""L2 correctness: the scan-based dense SimpleDP table vs the numpy oracle,
with and without the Pallas kernel, across instance shapes (hypothesis)."""

import numpy as np

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp  # noqa: E402

from hypothesis import given, settings, strategies as st  # noqa: E402

from compile.kernels import ref  # noqa: E402
from compile.model import simpledp_table  # noqa: E402


def random_instance(rng, k, max_gap=30, max_size=50, max_x=9):
    """Sorted disjoint files + multiplicities, as plain float64 arrays."""
    gaps = rng.integers(0, max_gap + 1, k)
    sizes = rng.integers(1, max_size + 1, k)
    l = np.zeros(k)
    pos = 0.0
    for i in range(k):
        pos += gaps[i]
        l[i] = pos
        pos += sizes[i]
    r = l + sizes
    x = rng.integers(1, max_x + 1, k).astype(np.float64)
    return l, r, x


def pad(l, r, x, k_pad):
    """Apply the runtime's padding contract: park at r[-1] with x = 0."""
    k = len(l)
    lp = np.full(k_pad, r[-1])
    rp = np.full(k_pad, r[-1])
    xp = np.zeros(k_pad)
    lp[:k], rp[:k], xp[:k] = l, r, x
    return lp, rp, xp


def table(l, r, x, u, ns_max, use_pallas):
    return np.asarray(
        simpledp_table(
            jnp.asarray(l), jnp.asarray(r), jnp.asarray(x), jnp.float64(u),
            ns_max=ns_max, use_pallas=use_pallas,
        )
    )


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(1, 10),
    u=st.sampled_from([0.0, 1.0, 7.0, 100.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_model_matches_ref_random(k, u, seed):
    rng = np.random.default_rng(seed)
    l, r, x = random_instance(rng, k)
    ns_max = int(x.sum()) + 1
    want = ref.dense_table_np(l, r, x, u, ns_max)
    for use_pallas in (False, True):
        got = table(l, r, x, u, ns_max, use_pallas)
        np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-9)


@settings(max_examples=15, deadline=None)
@given(k=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_padding_does_not_leak_into_real_rows(k, seed):
    rng = np.random.default_rng(seed)
    l, r, x = random_instance(rng, k)
    ns_max = int(x.sum()) + 1
    unpadded = table(l, r, x, 3.0, ns_max, True)[:k]
    lp, rp, xp = pad(l, r, x, k + 5)
    padded = table(lp, rp, xp, 3.0, ns_max, True)[:k]
    np.testing.assert_allclose(padded, unpadded, rtol=1e-12)


def test_root_cell_equals_known_optimum():
    # Two contiguous files, U=0: T[1,0] + VirtualLB must equal the best of
    # {no detour, atomic detour on f2} computed by hand.
    l = np.array([0.0, 10.0]); r = np.array([10.0, 30.0]); x = np.array([5.0, 1.0])
    m, u = 50.0, 0.0
    t = table(l, r, x, u, int(x.sum()) + 1, True)
    cost = t[1, 0] + ref.virtual_lb_np(l, r, x, u, m)
    # NoDetour: head 50->0, f1 served at 60, f2 at 80: 5*60 + 80 = 380.
    # Detour on f2: f2 at 50-10=40... serve f2 at 40+ s2=... compute: head
    # 50->l2=10 (40), sweep to 30: f2 served at 60, back at 10 at 80, f1
    # served at 90: 5*90 + 60 = 510. Optimum = 380.
    assert cost == 380.0


def test_scaled_positions_keep_precision():
    # GB-scale positions as used by the Rust runtime (POS_SCALE).
    rng = np.random.default_rng(7)
    l, r, x = random_instance(rng, 6, max_gap=200, max_size=170)
    ns_max = int(x.sum()) + 1
    want = ref.dense_table_np(l, r, x, 28.5, ns_max)
    got = table(l, r, x, 28.5, ns_max, True)
    np.testing.assert_allclose(got, want, rtol=1e-12)
