"""L1 correctness: the Pallas detour-min kernel vs the numpy oracle.

Hypothesis sweeps shapes and value regimes; every case asserts
``assert_allclose`` between the kernel (interpret mode) and ``ref.py``.
"""

import numpy as np
import pytest

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp  # noqa: E402

from hypothesis import given, settings, strategies as st  # noqa: E402

from compile.kernels import ref  # noqa: E402
from compile.kernels.simpledp_step import NS_BLK, detour_min_row  # noqa: E402


def run_both(tshift, a, b):
    got = np.asarray(detour_min_row(jnp.asarray(tshift), jnp.asarray(a), jnp.asarray(b)))
    want = ref.detour_min_row_np(tshift, a, b)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=0)
    return got


def test_single_candidate_affine():
    tshift = np.zeros((1, 8))
    a = np.array([2.0])
    b = np.array([5.0])
    got = run_both(tshift, a, b)
    np.testing.assert_allclose(got, 2.0 * np.arange(8) + 5.0)


def test_min_picks_crossing_lines():
    # Two candidates whose affine costs cross midway.
    ns_max = 16
    tshift = np.zeros((2, ns_max))
    a = np.array([1.0, 3.0])
    b = np.array([20.0, 0.0])
    got = run_both(tshift, a, b)
    ns = np.arange(ns_max)
    np.testing.assert_allclose(got, np.minimum(ns + 20.0, 3.0 * ns))


def test_masked_candidates_never_win():
    tshift = np.random.default_rng(0).uniform(0, 10, (4, 8))
    a = np.array([0.0, 0.0, 0.0, 0.0])
    b = np.array([ref.BIG, 1.0, ref.BIG, 2.0])
    got = run_both(tshift, a, b)
    want = np.minimum(tshift[1] + 1.0, tshift[3] + 2.0)
    np.testing.assert_allclose(got, want)


def test_multiblock_grid():
    # ns_max a multiple of NS_BLK exercises the tiled grid path.
    rng = np.random.default_rng(1)
    k, ns_max = 8, 2 * NS_BLK
    tshift = rng.uniform(0, 1e6, (k, ns_max))
    a = rng.uniform(0, 1e3, k)
    b = rng.uniform(0, 1e6, k)
    run_both(tshift, a, b)


@settings(max_examples=40, deadline=None)
@given(
    k=st.integers(1, 12),
    ns_pow=st.integers(0, 7),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([1.0, 1e4, 1e9]),
)
def test_kernel_matches_ref_random(k, ns_pow, seed, scale):
    rng = np.random.default_rng(seed)
    ns_max = 2**ns_pow
    tshift = rng.uniform(0, scale, (k, ns_max))
    a = rng.uniform(0, scale, k)
    b = rng.uniform(-scale, scale, k)
    # Randomly mask some candidates like L2 does.
    mask = rng.uniform(size=k) < 0.3
    b = np.where(mask, ref.BIG, b)
    a = np.where(mask, 0.0, a)
    if mask.all():
        b[0] = 0.0  # keep at least one valid candidate
    run_both(tshift, a, b)


@pytest.mark.parametrize("dtype", [np.float64])
def test_dtype_is_preserved(dtype):
    tshift = np.zeros((2, 4), dtype=dtype)
    out = detour_min_row(jnp.asarray(tshift), jnp.zeros(2), jnp.zeros(2))
    assert out.dtype == jnp.float64
