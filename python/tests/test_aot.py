"""AOT export smoke tests: HLO text generation and its shape contract."""

import os

import numpy as np

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp  # noqa: E402

from compile import aot  # noqa: E402
from compile.kernels import ref  # noqa: E402


def test_lower_bucket_produces_hlo_text():
    text = aot.lower_bucket(4, 16)
    assert text.startswith("HloModule")
    # Entry signature: 3 f64[4] vectors + 1 f64 scalar -> (f64[4,16]).
    assert "f64[4]" in text
    assert "f64[4,16]" in text
    assert "ENTRY" in text


def test_lower_bucket_no_pallas_variant_agrees_numerically():
    # Both variants must compute the same function; execute the jitted
    # versions (not the HLO) and compare against the oracle.
    from compile.model import simpledp_table

    rng = np.random.default_rng(3)
    l = np.array([0.0, 5.0, 20.0, 21.0])
    r = np.array([2.0, 9.0, 21.0, 29.0])
    x = np.array([2.0, 1.0, 4.0, 1.0])
    want = ref.dense_table_np(l, r, x, 1.5, 16)
    for use_pallas in (True, False):
        got = np.asarray(
            simpledp_table(
                jnp.asarray(l), jnp.asarray(r), jnp.asarray(x),
                jnp.float64(1.5), ns_max=16, use_pallas=use_pallas,
            )
        )
        np.testing.assert_allclose(got, want, rtol=1e-12)
    del rng


def test_default_buckets_match_rust_runtime():
    # Keep in sync with rust/src/runtime/xla_simpledp.rs::DEFAULT_BUCKETS.
    assert aot.BUCKETS == [(16, 128), (64, 1024), (128, 4096)]


def test_artifacts_exist_after_make(tmp_path):
    # Regenerate the smallest bucket into a temp dir and check naming.
    text = aot.lower_bucket(*aot.BUCKETS[0])
    k, ns = aot.BUCKETS[0]
    p = tmp_path / f"simpledp_{k}x{ns}.hlo.txt"
    p.write_text(text)
    assert os.path.getsize(p) > 1000
