"""Build-time compile path: JAX model + Pallas kernels + AOT export.

Never imported by the Rust runtime — artifacts are the only interface.
"""
