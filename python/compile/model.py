"""L2 JAX model: the dense SimpleDP table as a lax.scan wavefront.

Computes the full ``(K, NS)`` table ``T[b, ns]`` of the SimpleDP recurrence
(paper section 4.5) for a statically shaped bucket. The per-step detour
minimum — the O(K*NS) hot spot — runs in the L1 Pallas kernel
(``kernels.simpledp_step``); the O(NS) skip branch and the prefix-sum
bookkeeping stay in plain jnp where XLA fuses them.

This module is AOT-lowered once per shape bucket by ``aot.py`` and executed
from Rust through PJRT (``rust/src/runtime/``); it is never imported at
request time.

Inputs (all f64, positions pre-scaled by the caller, see POS_SCALE on the
Rust side):

  l: f64[K]  left end of each requested file (padded: parked at r[k-1])
  r: f64[K]  right end (same padding)
  x: f64[K]  request multiplicity (padded: 0)
  u: f64[]   U-turn penalty

Output: the f64[K, NS] table. Rows ``b >= k`` (padding) are junk by
contract; rows ``b < k`` never consult them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from .kernels.simpledp_step import detour_min_row  # noqa: E402

BIG = 1e30


@functools.partial(jax.jit, static_argnames=("ns_max", "use_pallas"))
def simpledp_table(l, r, x, u, *, ns_max, use_pallas=True):
    """Dense SimpleDP table ``T[b, ns]`` for one padded instance."""
    k = l.shape[0]
    ns = jnp.arange(ns_max, dtype=jnp.float64)
    c_idx = jnp.arange(k, dtype=jnp.float64)

    # Prefix sums (exclusive nl, inclusive lxi/nxi) — shared by every step.
    nl = jnp.concatenate([jnp.zeros(1), jnp.cumsum(x)[:-1]])
    lxi = jnp.cumsum(l * x)
    nxi = jnp.cumsum(x)

    # Base row: T[0, ns] = 2*s(0)*ns.
    row0 = 2.0 * (r[0] - l[0]) * ns
    table0 = jnp.zeros((k, ns_max), dtype=jnp.float64).at[0].set(row0)

    def step(table, b):
        # --- skip branch (plain jnp: one gather along ns) ---------------
        xb = x[b]
        shift = jnp.minimum(ns + xb, float(ns_max - 1)).astype(jnp.int32)
        prev = table[b - 1]
        skip = prev[shift] + 2.0 * (r[b] - r[b - 1]) * ns \
            + 2.0 * (l[b] - r[b - 1]) * xb

        # --- detour branch (L1 kernel): min over candidates c ------------
        # cand[c, ns] = T[c-1, ns] + A[c]*ns + B[c], for 1 <= c <= b.
        inner = (lxi[b] - lxi) - l * (nxi[b] - nxi)
        det2 = 2.0 * (u + r[b] - l)
        rprev = jnp.concatenate([jnp.zeros(1), r[:-1]])  # r[c-1]
        a_coef = 2.0 * (r[b] - rprev) + det2
        b_coef = det2 * nl + 2.0 * inner
        valid = (c_idx >= 1.0) & (c_idx <= jnp.float64(b))
        a_coef = jnp.where(valid, a_coef, 0.0)
        b_coef = jnp.where(valid, b_coef, BIG)
        tshift = jnp.concatenate([jnp.zeros((1, ns_max)), table[:-1]], axis=0)
        if use_pallas:
            detour = detour_min_row(tshift, a_coef, b_coef)
        else:
            cand = tshift + a_coef[:, None] * ns[None, :] + b_coef[:, None]
            detour = jnp.min(cand, axis=0)

        row = jnp.minimum(skip, detour)
        table = jax.lax.dynamic_update_slice(table, row[None, :], (b, 0))
        return table, ()

    table, _ = jax.lax.scan(step, table0, jnp.arange(1, k))
    return table


def model_fn(ns_max, use_pallas=True):
    """The function AOT-lowered per bucket: ``(l, r, x, u) -> (table,)``."""

    def fn(l, r, x, u):
        return (simpledp_table(l, r, x, u, ns_max=ns_max, use_pallas=use_pallas),)

    return fn
