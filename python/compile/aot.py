"""AOT export: lower the L2 SimpleDP model to HLO *text* per shape bucket.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the Rust side's bundled
XLA (xla_extension 0.5.1) rejects; the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Usage: ``python -m compile.aot --out ../artifacts`` (normally via
``make artifacts``). Buckets must stay in sync with
``rust/src/runtime/xla_simpledp.rs::DEFAULT_BUCKETS``.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import model_fn

# (K, NS) buckets — keep in sync with runtime::DEFAULT_BUCKETS.
BUCKETS = [(16, 128), (64, 1024), (128, 4096)]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bucket(k: int, ns: int, use_pallas: bool = True) -> str:
    vec = jax.ShapeDtypeStruct((k,), jnp.float64)
    scalar = jax.ShapeDtypeStruct((), jnp.float64)
    lowered = jax.jit(model_fn(ns, use_pallas=use_pallas)).lower(
        vec, vec, vec, scalar
    )
    return to_hlo_text(lowered)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="artifact dir")
    parser.add_argument(
        "--buckets",
        default=",".join(f"{k}x{ns}" for k, ns in BUCKETS),
        help="comma-separated KxNS bucket list",
    )
    parser.add_argument(
        "--no-pallas",
        action="store_true",
        help="lower the plain-jnp detour step instead of the Pallas kernel",
    )
    args = parser.parse_args()

    out_dir = args.out
    if out_dir.endswith(".hlo.txt"):  # Makefile passes the first target file
        out_dir = os.path.dirname(out_dir)
    os.makedirs(out_dir, exist_ok=True)

    for spec in args.buckets.split(","):
        k, ns = (int(v) for v in spec.strip().split("x"))
        text = lower_bucket(k, ns, use_pallas=not args.no_pallas)
        path = os.path.join(out_dir, f"simpledp_{k}x{ns}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
