"""L1 Pallas kernels + their pure-numpy/jnp correctness oracles."""

from . import ref  # noqa: F401
from .simpledp_step import detour_min_row, NS_BLK  # noqa: F401
