"""L1 Pallas kernel: the SimpleDP wavefront's detour-min step.

For a fixed file ``b`` the recurrence needs, for every skip count ``ns``::

    detour_min[ns] = min_{1<=c<=b} T[c-1, ns] + A[c]*ns + B[c]

where ``A``/``B`` are per-candidate scalars precomputed at L2 (invalid
candidates masked to +BIG). This is the O(K*NS) hot spot of the wavefront
— the skip branch is O(NS) and stays in plain jnp at L2.

TPU mapping (DESIGN.md section Hardware-Adaptation): the ``(c, ns)``
candidate plane is tiled along ``ns`` into VMEM blocks of ``(K, NS_BLK)``;
the min-reduction over ``c`` runs on the VPU (there is no matmul here, so
the MXU is idle by design). ``interpret=True`` everywhere: the CPU PJRT
client cannot execute Mosaic custom-calls, and interpret-mode lowers the
kernel to plain HLO ops that AOT-export cleanly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block width along the ns axis. 512 doubles * K=128 candidates = 512 KiB
# per VMEM block at the largest shipped bucket — comfortably under the
# ~16 MiB VMEM budget with double buffering.
NS_BLK = 512


def _detour_min_kernel(tshift_ref, a_ref, b_ref, out_ref):
    """One (K, NS_BLK) tile: min over candidates of an affine-in-ns plane."""
    ns0 = pl.program_id(0) * out_ref.shape[0]
    ns = ns0 + jax.lax.broadcasted_iota(jnp.float64, (1, out_ref.shape[0]), 1)
    cand = tshift_ref[...] + a_ref[...] * ns + b_ref[...]
    out_ref[...] = jnp.min(cand, axis=0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def detour_min_row(tshift, a, b, interpret=True):
    """``min_c tshift[c, ns] + a[c]*ns + b[c]`` for every ``ns``.

    Args:
      tshift: f64[K, NS] — rows ``T[c-1]`` of the table built so far
        (row 0 is junk; its candidate must be masked via ``a``/``b``).
      a, b:   f64[K]     — affine coefficients per candidate ``c``,
        pre-masked to +BIG for invalid candidates.
      interpret: keep True (see module docstring).

    Returns: f64[NS].
    """
    k, ns_max = tshift.shape
    if ns_max % NS_BLK == 0:
        blk = NS_BLK
    else:  # small test shapes: one block
        blk = ns_max
    grid = ns_max // blk
    return pl.pallas_call(
        _detour_min_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((k, blk), lambda j: (0, j)),
            pl.BlockSpec((k, 1), lambda j: (0, 0)),
            pl.BlockSpec((k, 1), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((ns_max,), jnp.float64),
        interpret=interpret,
    )(tshift, a[:, None], b[:, None])
