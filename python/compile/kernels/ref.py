"""Pure-numpy/jnp oracle for the dense SimpleDP wavefront (L1 correctness ref).

Mirrors ``rust/src/sched/simpledp_dense.rs`` (the exact ``i128`` twin) in
f64: the table ``T[b, ns]`` of the SimpleDP recurrence (paper section 4.5)
over a ``(K, NS)`` grid, where ``K`` is the padded number of requested
files and ``NS - 1`` the maximum total number of requests.

Recurrence (positions already rescaled; ``s(i) = r[i] - l[i]``)::

    T[0, ns]   = 2*s(0)*ns
    skip(b,ns) = T[b-1, min(ns+x[b], NS-1)] + 2*(r[b]-r[b-1])*ns
               + 2*(l[b]-r[b-1])*x[b]
    detour_c(b,ns) = T[c-1, ns] + 2*(r[b]-r[c-1])*ns
               + 2*(u + r[b]-l[c])*(ns + nl[c]) + 2*inner(c, b)
    inner(c,b) = sum_{c<f<=b} (l[f]-l[c])*x[f]
    T[b, ns]   = min(skip, min_{1<=c<=b} detour_c)

Padding contract: padded files (``x = 0``, zero size, parked at the right
end) only influence rows ``b >= k`` of the table, which callers never read.
"""

from __future__ import annotations

import numpy as np

BIG = 1e30  # +inf stand-in that survives arithmetic


def prefixes(l, r, x):
    """Shared prefix sums: ``nl`` (exclusive), ``lxi``/``nxi`` (inclusive)."""
    l = np.asarray(l, dtype=np.float64)
    x = np.asarray(x, dtype=np.float64)
    nl = np.concatenate([[0.0], np.cumsum(x)[:-1]])
    lxi = np.cumsum(l * x)
    nxi = np.cumsum(x)
    return nl, lxi, nxi


def dense_table_np(l, r, x, u, ns_max):
    """Reference table, plain numpy, straight from the recurrence."""
    l = np.asarray(l, dtype=np.float64)
    r = np.asarray(r, dtype=np.float64)
    x = np.asarray(x, dtype=np.float64)
    k = len(l)
    nl, lxi, nxi = prefixes(l, r, x)
    ns = np.arange(ns_max, dtype=np.float64)
    t = np.zeros((k, ns_max), dtype=np.float64)
    t[0] = 2.0 * (r[0] - l[0]) * ns
    for b in range(1, k):
        shift = np.minimum(np.arange(ns_max) + int(x[b]), ns_max - 1)
        skip = t[b - 1][shift] + 2.0 * (r[b] - r[b - 1]) * ns \
            + 2.0 * (l[b] - r[b - 1]) * x[b]
        best = skip
        for c in range(1, b + 1):
            inner = (lxi[b] - lxi[c]) - l[c] * (nxi[b] - nxi[c])
            cand = t[c - 1] + 2.0 * (r[b] - r[c - 1]) * ns \
                + 2.0 * (u + r[b] - l[c]) * (ns + nl[c]) + 2.0 * inner
            best = np.minimum(best, cand)
        t[b] = best
    return t


def detour_min_row_np(tshift, a, b_coef):
    """Reference for the L1 kernel alone: ``min_c tshift[c,ns] + a[c]*ns +
    b_coef[c]`` over axis 0 (invalid ``c`` pre-masked to +BIG in a/b)."""
    k, ns_max = tshift.shape
    ns = np.arange(ns_max, dtype=np.float64)
    cand = tshift + np.outer(a, ns) + b_coef[:, None]
    return cand.min(axis=0)


def virtual_lb_np(l, r, x, u, m):
    """``VirtualLB = sum_f x(f) * (m - l(f) + s(f) + u)`` (paper section 3)."""
    l = np.asarray(l, dtype=np.float64)
    r = np.asarray(r, dtype=np.float64)
    x = np.asarray(x, dtype=np.float64)
    return float(np.sum(x * (m - l + (r - l) + u)))
